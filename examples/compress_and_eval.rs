//! Method shoot-out on a trained checkpoint: all six methods at one ratio.
//!
//!   cargo run --release --example compress_and_eval -- [--model m]
//!       [--ratio 0.2] [--group 2] [--eval-batches 16]
//!
//! Requires a checkpoint (`drank train --model m`); falls back to the tiny
//! quickstart-style model when none exists.

use drank::calib::CalibOpts;
use drank::compress::{pipeline, CompressOpts, Method};
use drank::data::synlang::Domain;
use drank::data::DataBundle;
use drank::eval;
use drank::model::{ckpt_path, ModelConfig, Weights};
use drank::report::{fmt_ppl, Table};
use drank::runtime::Engine;
use drank::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let engine = Engine::open("artifacts")?;
    let model = args.str_or("model", "m");
    let weights = match Weights::load(&ckpt_path(&model)) {
        Ok((w, step)) => {
            println!("using checkpoint {} (step {step})", ckpt_path(&model));
            w
        }
        Err(_) => {
            println!("no checkpoint for {model}; training tiny stand-in (60 steps)");
            let cfg = ModelConfig::by_name("tiny")?;
            let data = DataBundle::build_cached(cfg.vocab, 1234, 1.0);
            let opts = drank::runtime::trainer::TrainOpts { steps: 60, ..Default::default() };
            drank::runtime::trainer::train(&engine, Weights::init(cfg, 0), &data, &opts)?
                .final_weights
        }
    };
    let data = DataBundle::build_cached(weights.config.vocab, 1234, 1.0);
    let ratio = args.f64_or("ratio", 0.2);
    let test = &data.domain(Domain::Wiki2s).test;
    let max_b = args.usize_or("eval-batches", 16);

    let dense_ppl = eval::ppl_dense(&engine, &weights, test, max_b)?;
    let mut table = Table::new(
        &format!("methods @ {:.0}% ({model})", ratio * 100.0),
        &["Method", "Achieved", "wiki2s PPL"],
    );
    table.row(vec!["Original".into(), "0.00".into(), fmt_ppl(dense_ppl)]);

    for method in [
        Method::PlainSvd,
        Method::Fwsvd,
        Method::Asvd,
        Method::SvdLlm,
        Method::BasisSharing,
        Method::DRank,
    ] {
        let opts = CompressOpts {
            method,
            ratio,
            group_layers: args.usize_or("group", 2),
            ..Default::default()
        };
        let copts = CalibOpts {
            batches: args.usize_or("calib-batches", 12),
            fisher: method == Method::Fwsvd,
            ..Default::default()
        };
        let (m, _) = pipeline::compress_model(&engine, &weights, &data, &copts, &opts)?;
        let ppl = eval::ppl_compressed(&engine, &m, test, max_b)?;
        table.row(vec![
            method.name().into(),
            format!("{:.2}", m.achieved_ratio()),
            fmt_ppl(ppl),
        ]);
        eprint!(".");
    }
    eprintln!();
    print!("{}", table.markdown());
    Ok(())
}
