//! Quickstart: the whole pipeline on the `tiny` config in under a minute.
//!
//!   cargo run --release --example quickstart
//!
//! Trains a tiny LM for a few steps, calibrates on synthetic WikiText-2,
//! compresses it with D-Rank at 30%, compares perplexity against the
//! uncompressed model and an equally-sized SVD-LLM baseline, then
//! generates a short continuation through the KV-cached decode path.

use drank::calib::CalibOpts;
use drank::compress::{pipeline, CompressOpts, Method};
use drank::data::synlang::Domain;
use drank::data::DataBundle;
use drank::eval;
use drank::model::fwd::{self, GenerateOpts};
use drank::model::{ModelConfig, Weights};
use drank::runtime::trainer::{train, TrainOpts};
use drank::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open("artifacts")?;
    let cfg = ModelConfig::by_name("tiny")?;
    let data = DataBundle::build_cached(cfg.vocab, 1234, 1.0);

    // 1. train briefly so the model has real structure
    println!("== training tiny LM (60 steps) ==");
    let opts = TrainOpts { steps: 60, log_every: 20, ..Default::default() };
    let log = train(&engine, Weights::init(cfg, 0), &data, &opts)?;
    for (s, l) in &log.losses {
        println!("  step {s:>3} loss {l:.3}");
    }
    let weights = log.final_weights;

    // 2. baseline perplexity
    let test = &data.domain(Domain::Wiki2s).test;
    let ppl0 = eval::ppl_dense(&engine, &weights, test, 16)?;
    println!("dense PPL: {ppl0:.2}");

    // 3. compress at 30% with D-Rank and with SVD-LLM
    let copts = CalibOpts { batches: 8, ..Default::default() };
    let mut compressed = None;
    for method in [Method::SvdLlm, Method::DRank] {
        let opts = CompressOpts { method, ratio: 0.3, group_layers: 2, ..Default::default() };
        let (model, _plan) = pipeline::compress_model(&engine, &weights, &data, &copts, &opts)?;
        let ppl = eval::ppl_compressed(&engine, &model, test, 16)?;
        println!(
            "{:<14} ratio {:.2}  PPL {ppl:.2}",
            method.name(),
            model.achieved_ratio()
        );
        if method == Method::DRank {
            compressed = Some(model);
        }
    }

    // 4. generate from the compressed model: one batched prefill of the
    //    prompt, then single-token KV-cached decode steps on the factors
    let model = compressed.expect("drank model");
    let prompt: Vec<i32> = test[..8].iter().map(|&t| t as i32).collect();
    let gopts = GenerateOpts { max_new_tokens: 12, ..Default::default() };
    let new_tokens = fwd::generate_model(&model, &prompt, &gopts);
    println!("greedy 12-token continuation of {prompt:?}: {new_tokens:?}");
    println!("done — see examples/e2e_train_compress_serve.rs for the full system");
    Ok(())
}
