//! End-to-end system driver (EXPERIMENTS.md §E2E).
//!
//!   cargo run --release --example e2e_train_compress_serve -- [--steps 300]
//!
//! Exercises every layer of the stack on one real workload:
//!   1. trains the `m` model (LLaMA-7B analog) on synthetic WikiText-2
//!      via the AOT train_step artifact (L1 Pallas kernels inside),
//!      logging the loss curve;
//!   2. calibrates (Gram/absmean statistics through the Pallas gram
//!      kernel) and compresses with D-Rank and Basis Sharing at 20%;
//!   3. evaluates PPL on all three domains + 7 zero-shot suites;
//!   4. serves batched scoring requests through the coordinator over the
//!      runtime-compiled factored graph, reporting latency/throughput.
//!
//! Writes runs/reports/e2e.json for EXPERIMENTS.md.

use drank::calib::CalibOpts;
use drank::compress::{pipeline, CompressOpts, Method};
use drank::coordinator::{Server, ServerOpts};
use drank::data::synlang::Domain;
use drank::data::DataBundle;
use drank::eval;
use drank::model::{ckpt_path, logical_model, Weights};
use drank::report::{fmt_acc, fmt_ppl, Table};
use drank::runtime::trainer::{train, TrainOpts};
use drank::runtime::Engine;
use drank::util::cli::Args;
use drank::util::json::Json;
use drank::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let engine = Engine::open("artifacts")?;
    let (cfg, seed) = logical_model("m")?;
    let data = DataBundle::build_cached(cfg.vocab, 1234, 1.0);

    // ---- 1. train (or reuse the checkpoint) --------------------------------
    let steps = args.usize_or("steps", 300);
    let weights = match Weights::load(&ckpt_path("m")) {
        Ok((w, s)) if !args.has("retrain") => {
            println!("[1/4] reusing checkpoint runs/m/model.bin (step {s})");
            w
        }
        _ => {
            println!("[1/4] training m for {steps} steps");
            let opts = TrainOpts { steps, seed, log_every: 25, ..Default::default() };
            let log = train(&engine, Weights::init(cfg, seed), &data, &opts)?;
            println!("  loss curve:");
            for (s, l) in &log.losses {
                println!("    step {s:>4}  loss {l:.4}");
            }
            println!("  training throughput: {:.0} tokens/s", log.tokens_per_sec);
            log.final_weights.save(&ckpt_path("m"), steps)?;
            log.final_weights
        }
    };

    // ---- 2. compress -------------------------------------------------------
    println!("[2/4] calibrating + compressing at 20%");
    let copts = CalibOpts { batches: 16, ..Default::default() };
    let mut models = Vec::new();
    for method in [Method::BasisSharing, Method::DRank] {
        let opts = CompressOpts { method, ratio: 0.2, group_layers: 2, ..Default::default() };
        let (m, plan) = pipeline::compress_model(&engine, &weights, &data, &copts, &opts)?;
        println!("  {:<14} achieved ratio {:.3}", method.name(), m.achieved_ratio());
        if method == Method::DRank {
            for (typ, ks) in &plan {
                println!("    {typ:<8} ranks {ks:?}");
            }
        }
        models.push((method, m));
    }

    // ---- 3. evaluate -------------------------------------------------------
    println!("[3/4] evaluating");
    let mut table = Table::new(
        "e2e: PPL + zero-shot @ 20%",
        &["Model", "wiki2s", "ptbs", "c4s", "Average*"],
    );
    let eval_row = |w: &Weights, name: &str, table: &mut Table| -> anyhow::Result<f64> {
        let mut cells = vec![name.to_string()];
        for d in [Domain::Wiki2s, Domain::Ptbs, Domain::C4s] {
            let ppl = eval::ppl_dense(&engine, w, &data.domain(d).test, 20)?;
            cells.push(fmt_ppl(ppl));
        }
        let (_, avg) =
            eval::tasks::run_all_suites(&engine, w, &data.tokenizer, &data.lexicon, 80, 17)?;
        cells.push(fmt_acc(avg));
        table.row(cells);
        Ok(avg)
    };
    eval_row(&weights, "Original", &mut table)?;
    for (method, m) in &models {
        eval_row(&m.to_dense(), method.name(), &mut table)?;
    }
    print!("{}", table.markdown());
    table.save_json("e2e")?;

    // ---- 4. serve ----------------------------------------------------------
    println!("[4/4] serving batched requests (D-Rank compressed)");
    let (_, drank_model) = models.pop().unwrap();
    let stream = data.domain(Domain::Wiki2s).test.clone();
    let server = Server::spawn(
        move || {
            let rt = drank::runtime::Runtime::cpu()?;
            drank::graph::compile_forward(&rt, &drank_model, cfg.batch, cfg.seq)
        },
        ServerOpts::default(),
    );
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let client = server.client();
        let stream = stream.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c);
            for _ in 0..25 {
                let start = rng.below(stream.len() - cfg.seq);
                client.score(stream[start..start + cfg.seq].to_vec()).expect("score");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.shutdown()?;
    println!(
        "  served {} reqs: {:.0} tok/s, p50 {:.1} ms, p99 {:.1} ms",
        m.requests,
        m.throughput_tps(),
        m.p50_ms(),
        m.p99_ms()
    );
    std::fs::write(
        "runs/reports/e2e_serving.json",
        Json::obj(vec![
            ("requests", Json::num(m.requests as f64)),
            ("tokens_per_sec", Json::num(m.throughput_tps())),
            ("p50_ms", Json::num(m.p50_ms())),
            ("p99_ms", Json::num(m.p99_ms())),
        ])
        .emit(),
    )?;
    println!("e2e complete — reports in runs/reports/");
    Ok(())
}
