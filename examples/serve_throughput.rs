//! Serving demo: multi-worker dynamic-batched scoring, dense vs compressed.
//!
//!   cargo run --release --example serve_throughput -- [--model m]
//!       [--ratio 0.4] [--requests 120] [--clients 4] [--workers 1]
//!       [--backend xla|ref]
//!
//! Mirrors the paper's Figure 4 setting: the compressed model's factored
//! matmuls do less work per token, so served throughput rises with the
//! compression ratio — and with `--workers N` the coordinator scales the
//! same workload across N backend instances. `--backend ref` runs the
//! pure-Rust reference forward end to end (random-init weights if no
//! checkpoint exists), so a bare checkout can drive the full stack, and
//! additionally exercises the KV-cached `Generate` endpoint (the xla
//! backend has no decode path, so that section is ref-only).

use drank::calib::CalibOpts;
use drank::compress::{pipeline, CompressOpts, Method};
use drank::coordinator::{spawn_model_server, ServerOpts};
use drank::data::synlang::Domain;
use drank::data::DataBundle;
use drank::model::load_or_init;
use drank::model::lowrank::CompressedModel;
use drank::runtime::Engine;
use drank::util::cli::Args;
use drank::util::rng::Rng;

fn run_load(
    model: CompressedModel,
    stream: Vec<u32>,
    requests: usize,
    clients: usize,
    workers: usize,
    backend: &str,
) -> anyhow::Result<drank::coordinator::Metrics> {
    let cfg = model.config();
    let sopts = ServerOpts { workers, ..Default::default() };
    let server = spawn_model_server(model, cfg.batch, cfg.seq, backend, sopts)?;
    let mut handles = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let stream = stream.clone();
        let seq = cfg.seq;
        let per = requests / clients;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            for _ in 0..per {
                let start = rng.below(stream.len() - seq);
                client.score(stream[start..start + seq].to_vec()).expect("score");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown()
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model_name = args.str_or("model", "m");
    let backend = args.str_or("backend", "xla");
    // checkpoint resolution: the named model, else any trained `tiny`
    // checkpoint, else (ref backend only) random-init weights — so the
    // example runs on a bare checkout with --backend ref
    let weights = load_or_init(&model_name, false)
        .or_else(|_| load_or_init("tiny", false))
        .or_else(|e| if backend == "ref" { load_or_init(&model_name, true) } else { Err(e) })?;
    let data = DataBundle::build_cached(weights.config.vocab, 1234, 1.0);
    let stream = data.domain(Domain::Wiki2s).test.clone();
    let requests = args.usize_or("requests", 120);
    let clients = args.usize_or("clients", 4);
    let workers = args.usize_or("workers", 1);
    let ratio = args.f64_or("ratio", 0.4);

    println!("== dense ({workers} worker(s), {backend} backend) ==");
    let dense = CompressedModel::dense_passthrough(weights.clone());
    let m0 = run_load(dense, stream.clone(), requests, clients, workers, &backend)?;
    println!(
        "throughput {:.0} tok/s, p50 {:.1} ms, p99 {:.1} ms, occupancy {:.2}, utilization {:.2}",
        m0.throughput_tps(),
        m0.p50_ms(),
        m0.p99_ms(),
        m0.mean_batch_occupancy(),
        m0.utilization()
    );

    println!("== compressed (D-Rank @ {:.0}%) ==", ratio * 100.0);
    let opts = CompressOpts { method: Method::DRank, ratio, ..Default::default() };
    let copts = CalibOpts { batches: 8, ..Default::default() };
    let compressed = if backend == "ref" {
        let (m, _) = pipeline::compress_model_reference(&weights, &data, &copts, &opts)?;
        m
    } else {
        let engine = Engine::open("artifacts")?;
        let (m, _) = pipeline::compress_model(&engine, &weights, &data, &copts, &opts)?;
        m // the server builds its own runtime; engine drops here
    };
    let m1 = run_load(compressed.clone(), stream.clone(), requests, clients, workers, &backend)?;
    println!(
        "throughput {:.0} tok/s, p50 {:.1} ms, p99 {:.1} ms, occupancy {:.2}, utilization {:.2}",
        m1.throughput_tps(),
        m1.p50_ms(),
        m1.p99_ms(),
        m1.mean_batch_occupancy(),
        m1.utilization()
    );
    println!(
        "speedup: {:.2}x",
        m1.throughput_tps() / m0.throughput_tps().max(1e-9)
    );

    // generation rides the same queue as scoring via the `Generate` request
    // kind; only the reference backend carries the KV-cached decode path
    if backend == "ref" {
        println!("== generation (KV-cached decode, compressed model) ==");
        let cfg = compressed.config();
        let (prompt_len, max_new) = (cfg.seq / 4, cfg.seq / 4);
        let gen_requests = args.usize_or("gen-requests", 8);
        let sopts = ServerOpts { workers, ..Default::default() };
        let server = spawn_model_server(compressed, cfg.batch, cfg.seq, "ref", sopts)?;
        let client = server.client();
        let mut rng = Rng::new(7);
        for r in 0..gen_requests {
            let start = rng.below(stream.len() - prompt_len);
            let resp = client
                .generate(stream[start..start + prompt_len].to_vec(), max_new)
                .expect("generate");
            if r == 0 {
                let shown = resp.tokens.len().min(12);
                println!("first continuation ({max_new} new): {:?}…", &resp.tokens[..shown]);
            }
        }
        drop(client);
        let mg = server.shutdown()?;
        println!(
            "{} generated tokens, {:.0} decode tok/s, p50 {:.1} ms",
            mg.generated_tokens,
            mg.decode_tps(),
            mg.p50_ms()
        );
    }
    Ok(())
}
