//! Serving demo: dynamic-batched scoring server, dense vs compressed.
//!
//!   cargo run --release --example serve_throughput -- [--model m]
//!       [--ratio 0.4] [--requests 120] [--clients 4]
//!
//! Mirrors the paper's Figure 4 setting: the compressed model's factored
//! matmuls do less work per token, so served throughput rises with the
//! compression ratio.

use drank::calib::CalibOpts;
use drank::compress::{pipeline, CompressOpts, Method};
use drank::coordinator::{Server, ServerOpts};
use drank::data::synlang::Domain;
use drank::data::DataBundle;
use drank::model::lowrank::CompressedModel;
use drank::model::{ckpt_path, Weights};
use drank::runtime::Engine;
use drank::util::cli::Args;
use drank::util::rng::Rng;

fn run_load(
    model: CompressedModel,
    stream: Vec<u32>,
    requests: usize,
    clients: usize,
) -> anyhow::Result<drank::coordinator::Metrics> {
    let cfg = model.config();
    let server = Server::spawn(
        move || {
            let rt = drank::runtime::Runtime::cpu()?;
            drank::graph::compile_forward(&rt, &model, cfg.batch, cfg.seq)
        },
        ServerOpts::default(),
    );
    let mut handles = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let stream = stream.clone();
        let seq = cfg.seq;
        let per = requests / clients;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            for _ in 0..per {
                let start = rng.below(stream.len() - seq);
                client.score(stream[start..start + seq].to_vec()).expect("score");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown()
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model_name = args.str_or("model", "m");
    let (weights, _) = Weights::load(&ckpt_path(&model_name))
        .or_else(|_| Weights::load(&ckpt_path("tiny")))
        .map_err(|_| anyhow::anyhow!("train a model first: drank train --model {model_name}"))?;
    let data = DataBundle::build_cached(weights.config.vocab, 1234, 1.0);
    let stream = data.domain(Domain::Wiki2s).test.clone();
    let requests = args.usize_or("requests", 120);
    let clients = args.usize_or("clients", 4);
    let ratio = args.f64_or("ratio", 0.4);

    println!("== dense ==");
    let dense = CompressedModel::dense_passthrough(weights.clone());
    let m0 = run_load(dense, stream.clone(), requests, clients)?;
    println!(
        "throughput {:.0} tok/s, p50 {:.1} ms, p99 {:.1} ms, occupancy {:.2}",
        m0.throughput_tps(),
        m0.p50_ms(),
        m0.p99_ms(),
        m0.mean_batch_occupancy()
    );

    println!("== compressed (D-Rank @ {:.0}%) ==", ratio * 100.0);
    let engine = Engine::open("artifacts")?;
    let opts = CompressOpts { method: Method::DRank, ratio, ..Default::default() };
    let copts = CalibOpts { batches: 8, ..Default::default() };
    let (compressed, _) = pipeline::compress_model(&engine, &weights, &data, &copts, &opts)?;
    drop(engine); // the server builds its own runtime
    let m1 = run_load(compressed, stream, requests, clients)?;
    println!(
        "throughput {:.0} tok/s, p50 {:.1} ms, p99 {:.1} ms, occupancy {:.2}",
        m1.throughput_tps(),
        m1.p50_ms(),
        m1.p99_ms(),
        m1.mean_batch_occupancy()
    );
    println!(
        "speedup: {:.2}x",
        m1.throughput_tps() / m0.throughput_tps().max(1e-9)
    );
    Ok(())
}
