"""L2: the tinylm transformer family in JAX (build-time only).

This module defines the *exact* model semantics that the Rust side
re-implements twice (pure-Rust reference forward in `rust/src/model/fwd.rs`
and the runtime XlaBuilder graph in `rust/src/graph/`). Any change here must
be mirrored there; the integration tests cross-check all three.

Architecture (LLaMA-class, no biases):
  - RMSNorm, eps = 1e-5:       y = x / sqrt(mean(x^2) + eps) * w
  - rotary position embedding: theta = 1e4, rotate-half convention
  - attention:                 causal, scale 1/sqrt(hd), GQA via head repeat
  - MLP:                       silu(x @ W_gate) * (x @ W_up) @ W_down
  - logits:                    rmsnorm(x) @ lm_head  (untied embedding)

Canonical parameter order (stacked per type — this is the wire format the
Rust runtime passes to every artifact, in this order):
   0 embed      [V, d]
   1 attn_norm  [L, d]
   2 wq         [L, d, d]
   3 wk         [L, d, kvd]      kvd = kv_heads * head_dim
   4 wv         [L, d, kvd]
   5 wo         [L, d, d]
   6 mlp_norm   [L, d]
   7 w_gate     [L, d, dff]
   8 w_up       [L, d, dff]
   9 w_down     [L, dff, d]
  10 final_norm [d]
  11 lm_head    [d, V]

All linear layers use the row-vector convention y = x @ W with
W in R^{d_in x d_out} — W_K of a GQA model is [d, kvd] with kvd < d,
matching the paper's LLaMA-3 W_K in R^{4096x1024}.
"""

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import flash_attention, gram_accum, lowrank_matmul
from .kernels.ref import mha_ref

EPS = 1e-5
ROPE_THETA = 1e4
N_PARAMS = 12
# compressible weight types, in canonical order
COMPRESSIBLE = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


@dataclasses.dataclass(frozen=True)
class Config:
    """Shape configuration of a tinylm variant."""

    name: str
    vocab: int
    d: int
    layers: int
    heads: int
    kv_heads: int
    dff: int
    seq: int
    batch: int

    @property
    def head_dim(self) -> int:
        return self.d // self.heads

    @property
    def kvd(self) -> int:
        return self.kv_heads * self.head_dim

    @property
    def gqa(self) -> bool:
        return self.kv_heads < self.heads

    def param_shapes(self):
        L, d, dff, V = self.layers, self.d, self.dff, self.vocab
        kvd = self.kvd
        return [
            ("embed", (V, d)),
            ("attn_norm", (L, d)),
            ("wq", (L, d, d)),
            ("wk", (L, d, kvd)),
            ("wv", (L, d, kvd)),
            ("wo", (L, d, d)),
            ("mlp_norm", (L, d)),
            ("w_gate", (L, d, dff)),
            ("w_up", (L, d, dff)),
            ("w_down", (L, dff, d)),
            ("final_norm", (d,)),
            ("lm_head", (d, V)),
        ]

    def matrix_dims(self, typ: str) -> Tuple[int, int]:
        """(d1, d2) of one layer's matrix of the given compressible type."""
        d, dff, kvd = self.d, self.dff, self.kvd
        return {
            "wq": (d, d),
            "wk": (d, kvd),
            "wv": (d, kvd),
            "wo": (d, d),
            "w_gate": (d, dff),
            "w_up": (d, dff),
            "w_down": (dff, d),
        }[typ]

    def kmax(self, typ: str) -> int:
        """Break-even rank: beyond this a factored layer is larger/slower."""
        d1, d2 = self.matrix_dims(typ)
        return (d1 * d2) // (d1 + d2)


# The model zoo. Multiple logical models (llama-7b / llama-2-7b analogs)
# share a shape config and therefore share HLO artifacts.
CONFIGS = {
    "tiny": Config("tiny", 256, 64, 2, 4, 4, 176, 64, 2),
    "s": Config("s", 512, 64, 4, 4, 4, 176, 96, 4),
    "m": Config("m", 512, 96, 6, 6, 6, 256, 96, 4),
    "l": Config("l", 512, 128, 8, 8, 8, 344, 96, 4),
    "gqa": Config("gqa", 512, 96, 6, 6, 2, 256, 96, 4),
    "mist": Config("mist", 512, 96, 6, 6, 3, 288, 96, 4),
}


def init_params(cfg: Config, key):
    """Normal(0, 0.02) init, norms at 1 (matches rust model::init)."""
    out = []
    for name, shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if "norm" in name:
            out.append(jnp.ones(shape, jnp.float32))
        else:
            out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
    return tuple(out)


def rmsnorm(x, w):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + EPS) * w


def rope_cos_sin(seq: int, hd: int):
    """[seq, hd/2] cos/sin tables, theta = 1e4."""
    half = hd // 2
    freqs = ROPE_THETA ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, T, H, hd]; rotate-half: (x1, x2) -> (x1 c - x2 s, x2 c + x1 s)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def _attention(x, wq, wk, wv, wo, cfg: Config, use_kernel: bool):
    """One attention block (pre-normed input x)."""
    B, T, d = x.shape
    H, KVH, hd = cfg.heads, cfg.kv_heads, cfg.head_dim
    q = (x @ wq).reshape(B, T, H, hd)
    k = (x @ wk).reshape(B, T, KVH, hd)
    v = (x @ wv).reshape(B, T, KVH, hd)
    cos, sin = rope_cos_sin(T, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if KVH != H:
        rep = H // KVH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    # [B, T, H, hd] -> [B, H, T, hd]
    q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    if use_kernel:
        o = flash_attention(
            q.reshape(B * H, T, hd),
            k.reshape(B * H, T, hd),
            v.reshape(B * H, T, hd),
        ).reshape(B, H, T, hd)
    else:
        o = mha_ref(q, k, v)  # differentiable reference path
    o = o.transpose(0, 2, 1, 3).reshape(B, T, d)
    return o @ wo, o  # (block output, input to wo)


def _mlp(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down, h  # (block output, input to w_down)


def forward_hidden(params, tokens, cfg: Config, use_kernel: bool):
    """Token ids -> final hidden states [B, T, d] (scan over layers)."""
    embed = params[0]
    x = embed[tokens]

    def block(x, layer):
        an, wq, wk, wv, wo, mn, wg, wu, wd = layer
        attn_out, _ = _attention(rmsnorm(x, an), wq, wk, wv, wo, cfg, use_kernel)
        x = x + attn_out
        mlp_out, _ = _mlp(rmsnorm(x, mn), wg, wu, wd)
        return x + mlp_out, None

    layers = tuple(params[i] for i in range(1, 10))
    x, _ = jax.lax.scan(block, x, layers)
    return rmsnorm(x, params[10])


def nll(params, tokens, cfg: Config, use_kernel: bool = True):
    """Per-token negative log likelihood. tokens [B, S] -> nll [B, S-1]."""
    h = forward_hidden(params, tokens[:, :-1], cfg, use_kernel)
    logits = h @ params[11]
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = tokens[:, 1:]
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return logz - picked


def mean_loss(params, tokens, cfg: Config, use_kernel: bool = False):
    return jnp.mean(nll(params, tokens, cfg, use_kernel))


# ----------------------------------------------------------------------------
# training (Adam + global-norm clipping)

ADAM_B1, ADAM_B2, ADAM_EPS, CLIP = 0.9, 0.95, 1e-8, 1.0
# Decoupled weight decay on matrix params (AdamW). Besides regularizing,
# this is what gives trained transformers their structured spectra: unused
# weight directions decay toward zero, so SVD truncation meaningfully
# separates signal from noise — the regime the paper's method targets.
WEIGHT_DECAY = 0.1


def train_step(params, m, v, step, lr, tokens, cfg: Config):
    """One AdamW step. Returns (loss, params', m', v').

    `step` is the 1-based step counter as f32 (bias correction);
    `lr` a f32 scalar so the Rust trainer owns the schedule.
    """
    loss, grads = jax.value_and_grad(mean_loss)(params, tokens, cfg)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads))
    scale = jnp.minimum(1.0, CLIP / (gnorm + 1e-12))
    grads = tuple(g * scale for g in grads)
    b1c = 1.0 - ADAM_B1**step
    b2c = 1.0 - ADAM_B2**step
    names = [n for n, _ in cfg.param_shapes()]
    new_p, new_m, new_v = [], [], []
    for name, p, mi, vi, g in zip(names, params, m, v, grads):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * jnp.square(g)
        update = (mi / b1c) / (jnp.sqrt(vi / b2c) + ADAM_EPS)
        wd = 0.0 if "norm" in name or name == "embed" else WEIGHT_DECAY
        new_p.append(p - lr * (update + wd * p))
        new_m.append(mi)
        new_v.append(vi)
    return loss, tuple(new_p), tuple(new_m), tuple(new_v)


# ----------------------------------------------------------------------------
# calibration statistics (grams for whitening, |x| means for ASVD)


def calib_stats(params, tokens, cfg: Config):
    """Per-layer input statistics for every compressible projection.

    Returns 8 arrays:
      g_attn [L,d,d], g_o [L,d,d], g_mlp [L,d,d], g_down [L,dff,dff]
      a_attn [L,d],   a_o [L,d],   a_mlp [L,d],   a_down [L,dff]
    where g_* = sum over tokens of X^T X (f32, via the Pallas gram kernel)
    and a_* = sum over tokens of |x|. The division by token count and the
    f64 re-accumulation across batches happen on the Rust side.
    """
    embed = params[0]
    x = embed[tokens]

    def block(x, layer):
        an, wq, wk, wv, wo, mn, wg, wu, wd = layer
        x_attn = rmsnorm(x, an)
        attn_out, x_o = _attention(x_attn, wq, wk, wv, wo, cfg, True)
        x = x + attn_out
        x_mlp = rmsnorm(x, mn)
        mlp_out, x_down = _mlp(x_mlp, wg, wu, wd)
        x = x + mlp_out

        def stats(t):
            flat = t.reshape(-1, t.shape[-1])
            return gram_accum(flat), jnp.sum(jnp.abs(flat), axis=0)

        ga, aa = stats(x_attn)
        go, ao = stats(x_o)
        gm, am = stats(x_mlp)
        gd, ad = stats(x_down)
        return x, (ga, go, gm, gd, aa, ao, am, ad)

    layers = tuple(params[i] for i in range(1, 10))
    _, ys = jax.lax.scan(block, x, layers)
    return ys


# ----------------------------------------------------------------------------
# Fisher rows (FWSVD): row-aggregated squared gradients of the LM loss


def fisher_rows(params, tokens, cfg: Config):
    """sum over output axis of grad^2, for each compressible type.

    Returns 7 arrays in COMPRESSIBLE order: [L, d_in] each.
    """
    grads = jax.grad(mean_loss)(params, tokens, cfg)
    idx = {"wq": 2, "wk": 3, "wv": 4, "wo": 5, "w_gate": 7, "w_up": 8, "w_down": 9}
    return tuple(jnp.sum(jnp.square(grads[idx[t]]), axis=-1) for t in COMPRESSIBLE)


# ----------------------------------------------------------------------------
# rank-padded low-rank forward (exercises the Pallas lowrank kernel) + LoRA

LORA_RANK, LORA_ALPHA = 8, 32.0


def lowrank_param_shapes(cfg: Config):
    """Factored parameter list: each compressible W becomes (B, C) padded to
    kpad = min(d1, d2); non-compressible params stay dense.

    Full-rank padding (not break-even kmax) because grouped Basis-Sharing
    allocations can exceed the per-layer break-even rank — zero columns are
    exact, so padded execution matches the unpadded factored model.

    Order: embed, attn_norm, (bq,cq), (bk,ck), (bv,cv), (bo,co), mlp_norm,
           (bg,cg), (bu,cu), (bd,cd), final_norm, lm_head   (19 tensors)
    """
    L = cfg.layers
    shapes = [("embed", (cfg.vocab, cfg.d)), ("attn_norm", (L, cfg.d))]
    for typ in ("wq", "wk", "wv", "wo"):
        d1, d2 = cfg.matrix_dims(typ)
        k = min(d1, d2)
        shapes += [(f"{typ}_b", (L, d1, k)), (f"{typ}_c", (L, k, d2))]
        if typ == "wo":
            shapes.append(("mlp_norm", (L, cfg.d)))
    for typ in ("w_gate", "w_up", "w_down"):
        d1, d2 = cfg.matrix_dims(typ)
        k = min(d1, d2)
        shapes += [(f"{typ}_b", (L, d1, k)), (f"{typ}_c", (L, k, d2))]
    shapes += [("final_norm", (cfg.d,)), ("lm_head", (cfg.d, cfg.vocab))]
    return shapes


def _lr_apply(x, b, c):
    """Factored linear over [B, T, d1] via the Pallas kernel."""
    Bz, T, d1 = x.shape
    y = lowrank_matmul(x.reshape(Bz * T, d1), b, c)
    return y.reshape(Bz, T, c.shape[-1])


def lowrank_forward_hidden(lr_params, tokens, cfg: Config, adapters=None):
    """Forward through the factored model; optional LoRA adapters.

    lr_params: tuple in lowrank_param_shapes order.
    adapters: optional tuple of 14 tensors (p, q per COMPRESSIBLE type),
              p [L, d1, r], q [L, r, d2]; y += (alpha/r) * x p q.
    """
    (embed, attn_norm, bq, cq, bk, ck, bv, cv, bo, co, mlp_norm,
     bg, cg, bu, cu, bd, cd, final_norm, lm_head) = lr_params
    x = embed[tokens]
    scale = LORA_ALPHA / LORA_RANK

    def proj(x, b, c, ad):
        y = _lr_apply(x, b, c)
        if ad is not None:
            p, q = ad
            y = y + scale * ((x @ p) @ q)
        return y

    def ad(i, l):
        if adapters is None:
            return None
        return (adapters[2 * i][l], adapters[2 * i + 1][l])

    B, T = tokens.shape
    H, KVH, hd = cfg.heads, cfg.kv_heads, cfg.head_dim
    cos, sin = rope_cos_sin(T, hd)
    for l in range(cfg.layers):
        xa = rmsnorm(x, attn_norm[l])
        q = proj(xa, bq[l], cq[l], ad(0, l)).reshape(B, T, H, hd)
        k = proj(xa, bk[l], ck[l], ad(1, l)).reshape(B, T, KVH, hd)
        v = proj(xa, bv[l], cv[l], ad(2, l)).reshape(B, T, KVH, hd)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        if KVH != H:
            k = jnp.repeat(k, H // KVH, axis=2)
            v = jnp.repeat(v, H // KVH, axis=2)
        qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        o = mha_ref(qt, kt, vt).transpose(0, 2, 1, 3).reshape(B, T, cfg.d)
        x = x + proj(o, bo[l], co[l], ad(3, l))
        xm = rmsnorm(x, mlp_norm[l])
        h = jax.nn.silu(proj(xm, bg[l], cg[l], ad(4, l))) * proj(
            xm, bu[l], cu[l], ad(5, l)
        )
        x = x + proj(h, bd[l], cd[l], ad(6, l))
    return rmsnorm(x, final_norm)


def lowrank_nll(lr_params, tokens, cfg: Config, adapters=None):
    h = lowrank_forward_hidden(lr_params, tokens[:, :-1], cfg, adapters)
    logits = h @ lr_params[-1]
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = tokens[:, 1:]
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return logz - picked


def lora_step(lr_params, adapters, m, v, step, lr, tokens, cfg: Config):
    """One Adam step on the LoRA adapters of a frozen compressed model."""

    def loss_fn(ad):
        return jnp.mean(lowrank_nll(lr_params, tokens, cfg, ad))

    loss, grads = jax.value_and_grad(loss_fn)(adapters)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads))
    scale = jnp.minimum(1.0, CLIP / (gnorm + 1e-12))
    grads = tuple(g * scale for g in grads)
    b1c = 1.0 - ADAM_B1**step
    b2c = 1.0 - ADAM_B2**step
    new_a, new_m, new_v = [], [], []
    for p, mi, vi, g in zip(adapters, m, v, grads):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * jnp.square(g)
        new_a.append(p - lr * (mi / b1c) / (jnp.sqrt(vi / b2c) + ADAM_EPS))
        new_m.append(mi)
        new_v.append(vi)
    return loss, tuple(new_a), tuple(new_m), tuple(new_v)


def adapter_shapes(cfg: Config):
    """14 tensors: (p, q) per compressible type, LoRA rank 8."""
    shapes = []
    for typ in COMPRESSIBLE:
        d1, d2 = cfg.matrix_dims(typ)
        shapes += [
            (f"{typ}_p", (cfg.layers, d1, LORA_RANK)),
            (f"{typ}_q", (cfg.layers, LORA_RANK, d2)),
        ]
    return shapes
