"""AOT export: lower L2 entry points to HLO *text* artifacts + manifest.

HLO text (NOT `lowered.compiler_ir('hlo').as_serialized_hlo_module_proto()`)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the published `xla` crate)
rejects with `proto.id() <= INT_MAX`; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Artifacts are pure functions of this package's sources; `make artifacts`
re-runs only when inputs change. Python never runs after this step.

Usage:
  python -m compile.aot --out ../artifacts [--configs tiny,m] [--kinds all]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _params_specs(cfg):
    return [_spec(s) for _, s in cfg.param_shapes()]


def _named(prefix, shapes, dtype="f32"):
    return [
        {"name": f"{prefix}{name}", "shape": list(shape), "dtype": dtype}
        for name, shape in shapes
    ]


def build_entry(cfg, kind):
    """Return (fn, example_specs, input_desc, output_desc) for an artifact."""
    B, S = cfg.batch, cfg.seq
    tok = _spec((B, S), jnp.int32)
    tok_desc = [{"name": "tokens", "shape": [B, S], "dtype": "i32"}]
    pshapes = cfg.param_shapes()
    pspecs = _params_specs(cfg)
    scalar = _spec(())

    if kind == "dense_nll":
        fn = lambda *a: (M.nll(a[:-1], a[-1], cfg, use_kernel=True),)
        specs = pspecs + [tok]
        ins = _named("", pshapes) + tok_desc
        outs = [{"name": "nll", "shape": [B, S - 1], "dtype": "f32"}]
    elif kind == "train_step":
        n = len(pspecs)

        def fn(*a):
            params, m, v = a[:n], a[n : 2 * n], a[2 * n : 3 * n]
            step, lr, tokens = a[3 * n], a[3 * n + 1], a[3 * n + 2]
            loss, p2, m2, v2 = M.train_step(params, m, v, step, lr, tokens, cfg)
            return (loss,) + p2 + m2 + v2

        specs = pspecs * 3 + [scalar, scalar, tok]
        ins = (
            _named("", pshapes)
            + _named("m_", pshapes)
            + _named("v_", pshapes)
            + [
                {"name": "step", "shape": [], "dtype": "f32"},
                {"name": "lr", "shape": [], "dtype": "f32"},
            ]
            + tok_desc
        )
        outs = (
            [{"name": "loss", "shape": [], "dtype": "f32"}]
            + _named("", pshapes)
            + _named("m_", pshapes)
            + _named("v_", pshapes)
        )
    elif kind == "calib":
        fn = lambda *a: M.calib_stats(a[:-1], a[-1], cfg)
        specs = pspecs + [tok]
        ins = _named("", pshapes) + tok_desc
        L, d, dff = cfg.layers, cfg.d, cfg.dff
        outs = _named(
            "",
            [
                ("g_attn", (L, d, d)),
                ("g_o", (L, d, d)),
                ("g_mlp", (L, d, d)),
                ("g_down", (L, dff, dff)),
                ("a_attn", (L, d)),
                ("a_o", (L, d)),
                ("a_mlp", (L, d)),
                ("a_down", (L, dff)),
            ],
        )
    elif kind == "fisher":
        fn = lambda *a: M.fisher_rows(a[:-1], a[-1], cfg)
        specs = pspecs + [tok]
        ins = _named("", pshapes) + tok_desc
        outs = _named(
            "f_",
            [(t, (cfg.layers, cfg.matrix_dims(t)[0])) for t in M.COMPRESSIBLE],
        )
    elif kind == "lowrank_nll":
        lshapes = M.lowrank_param_shapes(cfg)
        lspecs = [_spec(s) for _, s in lshapes]
        fn = lambda *a: (M.lowrank_nll(a[:-1], a[-1], cfg),)
        specs = lspecs + [tok]
        ins = _named("", lshapes) + tok_desc
        outs = [{"name": "nll", "shape": [B, S - 1], "dtype": "f32"}]
    elif kind == "lora_step":
        lshapes = M.lowrank_param_shapes(cfg)
        ashapes = M.adapter_shapes(cfg)
        nl, na = len(lshapes), len(ashapes)
        lspecs = [_spec(s) for _, s in lshapes]
        aspecs = [_spec(s) for _, s in ashapes]

        def fn(*a):
            lp = a[:nl]
            ad = a[nl : nl + na]
            m = a[nl + na : nl + 2 * na]
            v = a[nl + 2 * na : nl + 3 * na]
            step, lr, tokens = a[nl + 3 * na], a[nl + 3 * na + 1], a[-1]
            loss, a2, m2, v2 = M.lora_step(lp, ad, m, v, step, lr, tokens, cfg)
            return (loss,) + a2 + m2 + v2

        specs = lspecs + aspecs * 3 + [scalar, scalar, tok]
        ins = (
            _named("", lshapes)
            + _named("", ashapes)
            + _named("m_", ashapes)
            + _named("v_", ashapes)
            + [
                {"name": "step", "shape": [], "dtype": "f32"},
                {"name": "lr", "shape": [], "dtype": "f32"},
            ]
            + tok_desc
        )
        outs = (
            [{"name": "loss", "shape": [], "dtype": "f32"}]
            + _named("", ashapes)
            + _named("m_", ashapes)
            + _named("v_", ashapes)
        )
    else:
        raise ValueError(f"unknown kind {kind}")
    return fn, specs, ins, outs


ALL_KINDS = ["dense_nll", "train_step", "calib", "fisher", "lowrank_nll", "lora_step"]
# Full artifact set only where tests / LoRA need it; the rest get the core 4.
KIND_PLAN = {
    "tiny": ALL_KINDS,
    "s": ALL_KINDS[:4],
    "m": ALL_KINDS,
    "l": ALL_KINDS[:4],
    "gqa": ALL_KINDS[:4],
    "mist": ALL_KINDS[:4],
}


def export(cfg, kind, out_dir):
    fn, specs, ins, outs = build_entry(cfg, kind)
    # keep_unused: the wire format passes the full canonical parameter list
    # even to entry points that don't read every tensor (e.g. calib never
    # touches lm_head); without this XLA prunes the parameter and the Rust
    # side's argument count no longer matches the manifest.
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{cfg.name}_{kind}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    entry = {
        "file": fname,
        "config": cfg.name,
        "kind": kind,
        "shape": {
            "vocab": cfg.vocab,
            "d": cfg.d,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "kv_heads": cfg.kv_heads,
            "dff": cfg.dff,
            "seq": cfg.seq,
            "batch": cfg.batch,
        },
        "inputs": ins,
        "outputs": outs,
    }
    print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB, {len(ins)} in / {len(outs)} out)")
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="all")
    ap.add_argument("--kinds", default="plan")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = list(M.CONFIGS) if args.configs == "all" else args.configs.split(",")
    manifest = {"artifacts": []}
    for name in names:
        cfg = M.CONFIGS[name]
        kinds = KIND_PLAN[name] if args.kinds == "plan" else args.kinds.split(",")
        print(f"config {name}: {kinds}")
        for kind in kinds:
            manifest["artifacts"].append(export(cfg, kind, args.out))
    path = os.path.join(args.out, "manifest.json")
    # merge with a pre-existing manifest (partial re-exports)
    if os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        seen = {(a["config"], a["kind"]) for a in manifest["artifacts"]}
        for a in old["artifacts"]:
            if (a["config"], a["kind"]) not in seen:
                manifest["artifacts"].append(a)
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
