"""Fused low-rank matmul Pallas kernel: y = (x @ B) @ C.

This is the compressed linear layer — the inference hot-spot of every
SVD-compressed model. The paper's deployment target is a GPU two-GEMM
(cuBLAS calls with an HBM round-trip for the intermediate x@B); the TPU
re-think keeps the k-dimension intermediate resident in VMEM:

  grid = (m_tiles, n_tiles); each grid step
    - stages an (bm × d1) tile of x and the full (d1 × k) B through VMEM
      (B is small by construction: k << min(d1, d2)),
    - computes t = x_tile @ B once per m-tile (it is re-read from VMEM for
      every n-tile rather than recomputed from HBM),
    - emits o_tile = t @ C[:, n_tile].

VMEM footprint per step: bm*d1 + d1*k + k*bn + bm*bn floats. With the
paper-scale d1=4096, k<=1365, bm=bn=128: ~2.8 MiB << 16 MiB VMEM, leaving
room for double buffering. MXU utilization estimate in DESIGN.md §Perf.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n, target):
    """Largest divisor of n that is <= target (keeps grids exact)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _lowrank_kernel(x_ref, b_ref, c_ref, o_ref):
    # x_ref: [bm, d1], b_ref: [d1, k], c_ref: [k, bn], o_ref: [bm, bn]
    t = jnp.dot(x_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(t, c_ref[...], preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def lowrank_matmul(x, b, c, bm=64, bn=128):
    """y = (x @ b) @ c with 2-D [m, d1] x; see module docstring."""
    return _lowrank_fwd_impl(x, b, c, bm, bn)


def _lowrank_fwd_impl(x, b, c, bm, bn):
    m, d1 = x.shape
    _, k = b.shape
    _, d2 = c.shape
    bm = _pick_block(m, bm)
    bn = _pick_block(d2, bn)
    grid = (m // bm, d2 // bn)
    return pl.pallas_call(
        _lowrank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d1), lambda i, j: (i, 0)),
            pl.BlockSpec((d1, k), lambda i, j: (0, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d2), x.dtype),
        interpret=True,
    )(x, b, c)


def _lowrank_vjp_fwd(x, b, c, bm, bn):
    return _lowrank_fwd_impl(x, b, c, bm, bn), (x, b, c)


def _lowrank_vjp_bwd(bm, bn, res, g):
    # y = x B C; straightforward matmul adjoints (the factors are tiny, so
    # plain dots are already optimal here — no kernel needed on this path).
    x, b, c = res
    t = x @ b                       # [m, k]
    dx = (g @ c.T) @ b.T            # [m, d1]
    db = x.T @ (g @ c.T)            # [d1, k]
    dc = t.T @ g                    # [k, d2]
    return dx, db, dc


lowrank_matmul.defvjp(_lowrank_vjp_fwd, _lowrank_vjp_bwd)


def lowrank_apply(x, b, c):
    """Apply the factored layer to arbitrary-rank x ([..., d1])."""
    lead = x.shape[:-1]
    d1 = x.shape[-1]
    y = lowrank_matmul(x.reshape(-1, d1), b, c)
    return y.reshape(*lead, c.shape[-1])
