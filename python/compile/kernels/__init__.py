"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts)."""

from .attention import flash_attention
from .gram import gram_accum
from .lowrank import lowrank_apply, lowrank_matmul

__all__ = ["flash_attention", "gram_accum", "lowrank_apply", "lowrank_matmul"]
