"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an oracle here; pytest asserts
`assert_allclose(kernel(...), ref(...))` over hypothesis-driven shape/dtype
sweeps. The oracles are also what the L2 model uses on differentiated paths
where a kernel has no custom VJP.
"""

import jax.numpy as jnp


def lowrank_matmul_ref(x, b, c):
    """y = (x @ B) @ C — the factored linear layer.

    x: [..., d1], b: [d1, k], c: [k, d2] -> y: [..., d2]
    """
    return (x @ b) @ c


def gram_ref(x):
    """G = X^T X over all leading axes.

    x: [n, d] -> [d, d] (float32 accumulation).
    """
    x = x.astype(jnp.float32)
    return x.T @ x


def attention_ref(q, k, v, causal=True):
    """Masked scaled-dot-product attention, one head.

    q: [sq, hd], k: [skv, hd], v: [skv, hd] -> [sq, hd]
    """
    hd = q.shape[-1]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(hd, q.dtype))
    if causal:
        sq, skv = scores.shape
        # positions are aligned at the end (supports skv >= sq prefixes)
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        scores = jnp.where(ki <= qi, scores, jnp.finfo(scores.dtype).min)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return p @ v


def mha_ref(q, k, v, causal=True):
    """Batched multi-head attention via attention_ref semantics.

    q: [b, h, sq, hd], k/v: [b, h, skv, hd] -> [b, h, sq, hd]
    """
    hd = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(hd, q.dtype)
    )
    if causal:
        sq, skv = scores.shape[-2:]
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        scores = jnp.where(ki <= qi, scores, jnp.finfo(scores.dtype).min)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
