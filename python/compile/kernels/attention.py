"""Single-pass causal flash attention Pallas kernel.

The GPU flash-attention kernel assigns a threadblock per q-tile and streams
k/v tiles through shared memory with warp-level online softmax. The TPU
mapping: grid = (batch*heads, q_tiles); each grid step holds one q-tile in
VMEM and runs a fori_loop over kv-tiles, carrying the running max `m`,
normalizer `l`, and output accumulator in registers/VMEM — no HBM traffic
for intermediates and no separate softmax pass.

VMEM per step: bq*hd (q) + 2*skv*hd (k,v panel) + bq*bk (scores tile)
floats; at paper scale (skv=2048, hd=128, bq=bk=128): ~2.2 MiB. Causal
masking is done per-tile with global position indices, so fully-masked
tiles still stream (a real-TPU version would skip them via the grid;
noted in DESIGN.md §Perf).

interpret=True: Mosaic lowering is TPU-only; the CPU PJRT client executes
the interpreted HLO. Numerics validated against kernels.ref.mha_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _pick_block(n, target):
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, skv, causal):
    # q_ref: [1, bq, hd]; k_ref/v_ref: [1, skv, hd]; o_ref: [1, bq, hd]
    hd = q_ref.shape[-1]
    scale = 1.0 / (hd**0.5)
    q = q_ref[0].astype(jnp.float32) * scale            # [bq, hd]
    q_pos = pl.program_id(1) * bq + jax.lax.iota(jnp.int32, bq)

    def body(t, carry):
        acc, m_i, l_i = carry
        k_tile = k_ref[0, pl.dslice(t * bk, bk), :].astype(jnp.float32)
        v_tile = v_ref[0, pl.dslice(t * bk, bk), :].astype(jnp.float32)
        s = q @ k_tile.T                                 # [bq, bk]
        if causal:
            k_pos = t * bk + jax.lax.iota(jnp.int32, bk)
            mask = k_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=-1))         # [bq]
        p = jnp.exp(s - m_new[:, None])                  # [bq, bk]
        alpha = jnp.exp(m_i - m_new)                     # [bq]
        l_new = alpha * l_i + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v_tile
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, skv // bk, body, (acc0, m0, l0))
    o_ref[0] = (acc / l_i[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, bq=64, bk=64):
    """Causal attention over flattened heads.

    q, k, v: [bh, s, hd] (bh = batch*heads; k/v already GQA-expanded)
    returns [bh, s, hd].
    """
    bh, s, hd = q.shape
    skv = k.shape[1]
    bq = _pick_block(s, bq)
    bk = _pick_block(skv, bk)
    grid = (bh, s // bq)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, skv=skv, causal=causal
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, skv, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, skv, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        interpret=True,
    )(q, k, v)
