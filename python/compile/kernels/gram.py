"""Gram accumulation Pallas kernel: G = X^T X.

The calibration hot loop: every compression method here whitens with (or
scales by statistics of) the per-projection input Gram matrix, accumulated
over 10^5..10^6 calibration tokens. On GPU the usual mapping is split-K
with atomics; the TPU mapping is a grid reduction:

  grid = (k_tiles,) over the token axis; each step loads a (bk × d) slice
  of X into VMEM and accumulates X_tile^T X_tile into the (d × d) output
  block, which stays resident in VMEM across the whole grid (the output
  index_map is constant — the canonical TPU accumulation pattern).

VMEM per step: bk*d + d*d floats; paper-scale d=4096 needs f32 d×d = 64 MiB
so the real-TPU variant tiles d into 128-column panels; at our scales
(d<=512) the whole Gram fits VMEM directly and we keep the simple schedule.
Accumulation is always f32 regardless of input dtype (whitening is
precision-critical; the paper uses FP64 for S — we re-accumulate in f64 on
the Rust side from per-batch f32 partials).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n, target):
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _gram_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x.T, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnums=(1,))
def gram_accum(x, bk=128):
    """G = X^T X for x: [n, d] -> [d, d] float32."""
    n, d = x.shape
    bk = _pick_block(n, bk)
    grid = (n // bk,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bk, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
    )(x)
