"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against kernels/ref.py.
This is the CORE correctness signal for everything the artifacts compute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import flash_attention, gram_accum, lowrank_matmul
from compile.kernels.lowrank import lowrank_apply
from compile.kernels.ref import attention_ref, gram_ref, lowrank_matmul_ref, mha_ref

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- lowrank


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 3, 8, 64, 96]),
    d1=st.sampled_from([16, 64, 192]),
    k=st.sampled_from([1, 8, 48]),
    d2=st.sampled_from([16, 64, 176]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lowrank_matches_ref(m, d1, k, d2, seed):
    r = rng(seed)
    x = r.standard_normal((m, d1), dtype=np.float32)
    b = r.standard_normal((d1, k), dtype=np.float32)
    c = r.standard_normal((k, d2), dtype=np.float32)
    got = lowrank_matmul(jnp.asarray(x), jnp.asarray(b), jnp.asarray(c))
    want = lowrank_matmul_ref(x, b, c)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_dtypes(dtype):
    r = rng(0)
    x = jnp.asarray(r.standard_normal((32, 64)), dtype)
    b = jnp.asarray(r.standard_normal((64, 8)), dtype)
    c = jnp.asarray(r.standard_normal((8, 48)), dtype)
    got = lowrank_matmul(x, b, c)
    assert got.dtype == dtype
    want = lowrank_matmul_ref(
        x.astype(jnp.float32), b.astype(jnp.float32), c.astype(jnp.float32)
    )
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
    )


def test_lowrank_apply_leading_axes():
    r = rng(1)
    x = jnp.asarray(r.standard_normal((2, 6, 32), dtype=np.float32))
    b = jnp.asarray(r.standard_normal((32, 4), dtype=np.float32))
    c = jnp.asarray(r.standard_normal((4, 24), dtype=np.float32))
    got = lowrank_apply(x, b, c)
    assert got.shape == (2, 6, 24)
    assert_allclose(
        np.asarray(got), np.asarray((x @ b) @ c), rtol=2e-5, atol=2e-5
    )


def test_lowrank_custom_vjp_matches_autodiff():
    """Gradients through the kernel == gradients through the reference."""
    r = rng(2)
    x = jnp.asarray(r.standard_normal((16, 24), dtype=np.float32))
    b = jnp.asarray(r.standard_normal((24, 4), dtype=np.float32))
    c = jnp.asarray(r.standard_normal((4, 20), dtype=np.float32))

    def f_kernel(x, b, c):
        return jnp.sum(jnp.sin(lowrank_matmul(x, b, c)))

    def f_ref(x, b, c):
        return jnp.sum(jnp.sin((x @ b) @ c))

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(x, b, c)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, b, c)
    for a, bb in zip(g1, g2):
        assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- gram


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 7, 64, 128, 384]),
    d=st.sampled_from([8, 64, 192]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matches_ref(n, d, seed):
    r = rng(seed)
    x = r.standard_normal((n, d), dtype=np.float32)
    got = np.asarray(gram_accum(jnp.asarray(x)))
    want = np.asarray(gram_ref(x))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gram_is_symmetric_psd():
    r = rng(3)
    x = jnp.asarray(r.standard_normal((100, 32), dtype=np.float32))
    g = np.asarray(gram_accum(x))
    assert_allclose(g, g.T, rtol=1e-6, atol=1e-6)
    w = np.linalg.eigvalsh(g.astype(np.float64))
    assert w.min() > -1e-3


# ---------------------------------------------------------------- attention


@settings(max_examples=20, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 8]),
    s=st.sampled_from([16, 64, 96]),
    hd=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_ref(bh, s, hd, seed):
    r = rng(seed)
    q = r.standard_normal((bh, s, hd), dtype=np.float32)
    k = r.standard_normal((bh, s, hd), dtype=np.float32)
    v = r.standard_normal((bh, s, hd), dtype=np.float32)
    got = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)))
    want = np.stack(
        [np.asarray(attention_ref(q[i], k[i], v[i])) for i in range(bh)]
    )
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_blocking_invariance():
    """Result must not depend on tile sizes (online softmax correctness)."""
    r = rng(4)
    q = jnp.asarray(r.standard_normal((2, 64, 16), dtype=np.float32))
    k = jnp.asarray(r.standard_normal((2, 64, 16), dtype=np.float32))
    v = jnp.asarray(r.standard_normal((2, 64, 16), dtype=np.float32))
    a = flash_attention(q, k, v, True, 64, 64)
    b = flash_attention(q, k, v, True, 16, 8)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_flash_attention_is_causal():
    """Changing future tokens must not change past outputs."""
    r = rng(5)
    q = jnp.asarray(r.standard_normal((1, 32, 16), dtype=np.float32))
    k = np.asarray(r.standard_normal((1, 32, 16), dtype=np.float32))
    v = np.asarray(r.standard_normal((1, 32, 16), dtype=np.float32))
    out1 = np.asarray(flash_attention(q, jnp.asarray(k), jnp.asarray(v)))
    k2, v2 = k.copy(), v.copy()
    k2[:, 20:], v2[:, 20:] = 9.0, -9.0
    out2 = np.asarray(flash_attention(q, jnp.asarray(k2), jnp.asarray(v2)))
    assert_allclose(out1[:, :20], out2[:, :20], rtol=1e-5, atol=1e-5)
    assert not np.allclose(out1[:, 21:], out2[:, 21:])


def test_mha_ref_gqa_equivalence():
    """mha over repeated kv == per-head ref with shared kv (GQA semantics)."""
    r = rng(6)
    q = r.standard_normal((1, 4, 16, 8), dtype=np.float32)
    k1 = r.standard_normal((1, 1, 16, 8), dtype=np.float32)
    v1 = r.standard_normal((1, 1, 16, 8), dtype=np.float32)
    k = np.repeat(k1, 4, axis=1)
    v = np.repeat(v1, 4, axis=1)
    out = np.asarray(mha_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)))
    for h in range(4):
        want = np.asarray(attention_ref(q[0, h], k1[0, 0], v1[0, 0]))
        assert_allclose(out[0, h], want, rtol=1e-5, atol=1e-5)
