"""L2 model semantics: shapes, path equivalences, training behaviour.

These pin down the exact semantics the Rust side re-implements.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.kernels.gram import gram_accum

jax.config.update("jax_platform_name", "cpu")

CFG = M.CONFIGS["tiny"]
GQA_CFG = M.Config("tiny_gqa", 256, 64, 2, 4, 2, 176, 64, 2)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    r = np.random.default_rng(0)
    return jnp.asarray(r.integers(0, CFG.vocab, (CFG.batch, CFG.seq)), jnp.int32)


def test_param_shapes_canonical_order(params):
    names = [n for n, _ in CFG.param_shapes()]
    assert names == [
        "embed", "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm",
        "w_gate", "w_up", "w_down", "final_norm", "lm_head",
    ]
    for p, (_, shape) in zip(params, CFG.param_shapes()):
        assert p.shape == shape


def test_rmsnorm_matches_manual():
    r = np.random.default_rng(1)
    x = jnp.asarray(r.standard_normal((3, 8), dtype=np.float32))
    w = jnp.asarray(r.standard_normal(8, dtype=np.float32))
    got = M.rmsnorm(x, w)
    want = x / np.sqrt(np.mean(np.square(np.asarray(x)), -1, keepdims=True) + 1e-5) * w
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_rope_norm_preserving():
    """Rotary is a rotation: per-pair norms are preserved."""
    r = np.random.default_rng(2)
    x = jnp.asarray(r.standard_normal((1, 16, 2, 32), dtype=np.float32))
    cos, sin = M.rope_cos_sin(16, 32)
    y = np.asarray(M.apply_rope(x, cos, sin))
    x = np.asarray(x)
    n_x = x[..., :16] ** 2 + x[..., 16:] ** 2
    n_y = y[..., :16] ** 2 + y[..., 16:] ** 2
    assert_allclose(n_x, n_y, rtol=1e-4, atol=1e-5)


def test_rope_position_zero_identity():
    x = jnp.ones((1, 4, 1, 16), jnp.float32)
    cos, sin = M.rope_cos_sin(4, 16)
    y = np.asarray(M.apply_rope(x, cos, sin))
    assert_allclose(y[0, 0], np.ones((1, 16)), rtol=1e-6, atol=1e-6)


def test_nll_kernel_and_ref_paths_agree(params, tokens):
    """Pallas flash-attention path == jnp reference path."""
    a = M.nll(params, tokens, CFG, use_kernel=True)
    b = M.nll(params, tokens, CFG, use_kernel=False)
    assert a.shape == (CFG.batch, CFG.seq - 1)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_nll_is_positive_and_reasonable(params, tokens):
    nll = np.asarray(M.nll(params, tokens, CFG))
    assert np.isfinite(nll).all()
    # fresh random model over V=256 ≈ uniform: nll ≈ log(256) ≈ 5.55
    assert abs(nll.mean() - np.log(CFG.vocab)) < 1.0


def test_gqa_forward_shapes():
    params = M.init_params(GQA_CFG, jax.random.PRNGKey(1))
    assert params[3].shape == (2, 64, 32)  # wk slimmed: kvd = 2 * 16
    r = np.random.default_rng(3)
    toks = jnp.asarray(r.integers(0, 256, (2, 64)), jnp.int32)
    nll = M.nll(params, toks, GQA_CFG)
    assert np.isfinite(np.asarray(nll)).all()


def test_gqa_with_repeated_kv_equals_mha():
    """A GQA model whose kv heads are replicated == the MHA model."""
    mha = M.init_params(CFG, jax.random.PRNGKey(2))
    gqa = list(M.init_params(GQA_CFG, jax.random.PRNGKey(2)))
    # build MHA wk/wv by repeating each GQA kv head across the group
    hd = GQA_CFG.head_dim
    rep = CFG.heads // GQA_CFG.kv_heads
    for idx in (3, 4):
        w = np.asarray(gqa[idx])  # [L, d, kvd]
        L, d, kvd = w.shape
        heads = w.reshape(L, d, GQA_CFG.kv_heads, hd)
        full = np.repeat(heads, rep, axis=2).reshape(L, d, CFG.heads * hd)
        mha = list(mha)
        mha[idx] = jnp.asarray(full)
    # share every other weight
    for i in range(12):
        if i not in (3, 4):
            mha[i] = gqa[i]
    r = np.random.default_rng(4)
    toks = jnp.asarray(r.integers(0, 256, (2, 64)), jnp.int32)
    a = M.nll(tuple(mha), toks, CFG, use_kernel=False)
    b = M.nll(tuple(gqa), toks, GQA_CFG, use_kernel=False)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_train_step_learns_repetition():
    """A few steps on a constant batch must reduce its loss."""
    cfg = M.CONFIGS["tiny"]
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    m = tuple(jnp.zeros_like(p) for p in params)
    v = tuple(jnp.zeros_like(p) for p in params)
    r = np.random.default_rng(5)
    toks = jnp.asarray(r.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32)
    step_fn = jax.jit(
        lambda p, m, v, s, t: M.train_step(p, m, v, s, 3e-3, t, cfg)
    )
    losses = []
    for s in range(8):
        loss, params, m, v = step_fn(params, m, v, float(s + 1), toks)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_calib_stats_layer0_gram_matches_direct(params, tokens):
    """g_attn[0] must equal gram of rmsnorm(embed[tokens], attn_norm[0])."""
    outs = M.calib_stats(params, tokens, CFG)
    g_attn = np.asarray(outs[0])
    x = params[0][tokens]
    x0 = M.rmsnorm(x, params[1][0]).reshape(-1, CFG.d)
    want = np.asarray(gram_accum(x0))
    assert_allclose(g_attn[0], want, rtol=1e-3, atol=1e-3)
    # symmetry + PSD for all grams
    for gi in range(4):
        g = np.asarray(outs[gi]).astype(np.float64)
        for l in range(CFG.layers):
            assert_allclose(g[l], g[l].T, rtol=1e-4, atol=1e-2)
            assert np.linalg.eigvalsh(g[l]).min() > -1e-2
    # absmean sums are nonnegative
    for ai in range(4, 8):
        assert np.asarray(outs[ai]).min() >= 0.0


def test_fisher_rows_match_finite_difference(params, tokens):
    """Spot-check d(loss)/d(wq[0,i,:]) row energy via central differences."""
    rows = M.fisher_rows(params, tokens, CFG)
    assert len(rows) == 7
    f_q = np.asarray(rows[0])
    assert f_q.shape == (CFG.layers, CFG.d)
    assert (np.asarray(r).min() >= 0.0 for r in rows)
    # FD on two coordinates of wq[0]
    g = jax.grad(M.mean_loss)(params, tokens, CFG)[2]
    eps = 1e-3
    for (i, j) in [(0, 0), (5, 7)]:
        w = np.asarray(params[2])
        wp, wm = w.copy(), w.copy()
        wp[0, i, j] += eps
        wm[0, i, j] -= eps
        pp = list(params); pp[2] = jnp.asarray(wp)
        pm = list(params); pm[2] = jnp.asarray(wm)
        fd = (
            float(M.mean_loss(tuple(pp), tokens, CFG))
            - float(M.mean_loss(tuple(pm), tokens, CFG))
        ) / (2 * eps)
        assert abs(fd - float(g[0, i, j])) < 5e-3


def _svd_factors(w, k):
    u, s, vt = np.linalg.svd(np.asarray(w, np.float64), full_matrices=False)
    b = (u[:, :k] * s[:k]).astype(np.float32)
    c = vt[:k].astype(np.float32)
    return b, c


def _padded_lowrank_params(params, cfg):
    """Exact factorization of each W padded with zeros to kmax."""
    lp = [params[0], params[1]]
    by_type = {"wq": 2, "wk": 3, "wv": 4, "wo": 5, "w_gate": 7, "w_up": 8,
               "w_down": 9}
    order = ["wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down"]
    for typ in order:
        if typ == "mlp_norm":
            lp.append(params[6])
            continue
        w = np.asarray(params[by_type[typ]])
        L = w.shape[0]
        d1, d2 = cfg.matrix_dims(typ)
        kmax = min(d1, d2)
        bs = np.zeros((L, d1, kmax), np.float32)
        cs = np.zeros((L, kmax, d2), np.float32)
        for l in range(L):
            k = min(kmax, min(d1, d2))
            b, c = _svd_factors(w[l], k)
            bs[l, :, :k] = b
            cs[l, :k] = c
        lp += [jnp.asarray(bs), jnp.asarray(cs)]
    lp += [params[10], params[11]]
    return tuple(lp)


def test_lowrank_nll_matches_dense_reconstruction(params, tokens):
    """Factored path at full break-even rank ~= dense path with the same
    truncated reconstruction (here rank kmax >= full rank for square mats is
    false, so compare against dense model rebuilt from B@C)."""
    lp = _padded_lowrank_params(params, CFG)
    got = np.asarray(M.lowrank_nll(lp, tokens, CFG))
    # rebuild an equivalent dense model from the factors
    dense = list(params)
    lpi = {"wq": 2, "wk": 4, "wv": 6, "wo": 8, "w_gate": 11, "w_up": 13,
           "w_down": 15}
    pi = {"wq": 2, "wk": 3, "wv": 4, "wo": 5, "w_gate": 7, "w_up": 8,
          "w_down": 9}
    for typ, li in lpi.items():
        b, c = np.asarray(lp[li]), np.asarray(lp[li + 1])
        dense[pi[typ]] = jnp.asarray(np.einsum("lik,lkj->lij", b, c))
    want = np.asarray(M.nll(tuple(dense), tokens, CFG, use_kernel=False))
    assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_lora_step_reduces_loss(params, tokens):
    lp = _padded_lowrank_params(params, CFG)
    adapters, m, v = [], [], []
    r = np.random.default_rng(7)
    for name, shape in M.adapter_shapes(CFG):
        init = (
            0.02 * r.standard_normal(shape).astype(np.float32)
            if name.endswith("_p")
            else np.zeros(shape, np.float32)
        )
        adapters.append(jnp.asarray(init))
        m.append(jnp.zeros(shape, jnp.float32))
        v.append(jnp.zeros(shape, jnp.float32))
    adapters, m, v = tuple(adapters), tuple(m), tuple(v)
    step_fn = jax.jit(
        lambda a, m, v, s, t: M.lora_step(lp, a, m, v, s, 1e-3, t, CFG)
    )
    losses = []
    for s in range(6):
        loss, adapters, m, v = step_fn(adapters, m, v, float(s + 1), tokens)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_zero_adapters_are_identity(params, tokens):
    """q-side zeros => adapters contribute nothing."""
    lp = _padded_lowrank_params(params, CFG)
    adapters = []
    r = np.random.default_rng(8)
    for name, shape in M.adapter_shapes(CFG):
        init = (
            0.5 * r.standard_normal(shape).astype(np.float32)
            if name.endswith("_p")
            else np.zeros(shape, np.float32)
        )
        adapters.append(jnp.asarray(init))
    a = np.asarray(M.lowrank_nll(lp, tokens, CFG, tuple(adapters)))
    b = np.asarray(M.lowrank_nll(lp, tokens, CFG, None))
    assert_allclose(a, b, rtol=1e-5, atol=1e-5)
