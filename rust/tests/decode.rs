//! Contract suite for the KV-cached prefill/decode path (`model::fwd`).
//!
//! Three contracts, all artifact-free:
//!  (a) prefill over a prompt followed by k teacher-forced decode steps
//!      reproduces the full-forward logits of a frozen scalar oracle to
//!      1e-5 at every position — on the tiny and GQA configs, for dense
//!      weights and for a compressed model decoding on its factors;
//!  (b) prefill and decode logits are bit-identical (`to_bits`) across
//!      1/2/4 threads — prefill inherits the batched forward's
//!      determinism, decode is serial by construction;
//!  (c) a `Generate` request served through the coordinator's
//!      `RefBackend` returns exactly the tokens the direct in-process
//!      `fwd::generate` loop produces, with zero `Reconstruct` stage
//!      calls — factored weights decode on their factors, never through
//!      rematerialized dense matrices. (Keep this binary free of
//!      `to_dense()`: the stage counters are process-global.)

use std::sync::Mutex;

use drank::calib::CalibStats;
use drank::compress::{methods, CompressOpts, Method};
use drank::coordinator::{spawn_model_server, ServerOpts};
use drank::model::fwd::{self, GenerateOpts};
use drank::model::lowrank::CompressedModel;
use drank::model::{ModelConfig, Weights};
use drank::util::parallel::set_threads;
use drank::util::profile::{self, Stage};
use drank::util::rng::Rng;

/// `set_threads` is process-global; serialize tests that touch it.
static THREAD_LOCK: Mutex<()> = Mutex::new(());

fn compress_drank(w: &Weights, calib_seed: u64) -> CompressedModel {
    let stats = CalibStats::synthetic(&w.config, calib_seed);
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.3,
        group_layers: 2,
        ..Default::default()
    };
    let (model, _) = methods::compress(w, &stats, &opts).unwrap();
    assert!(model.achieved_ratio() > 0.0, "compression was vacuous");
    model
}

// ---------------------------------------------------------- scalar oracle
//
// A frozen scalar full-prefix forward returning the *logits at the last
// position* — the quantity one prefill or decode step emits. Shares no
// code with the implementation under test; factored sites run the same
// association the serving path uses, `(x·B)·C`, so the 1e-5 contract is
// about the cache machinery, not the factorization gap.
mod oracle {
    use drank::model::lowrank::{CompressedModel, Linear};

    const EPS: f32 = 1e-5;
    const ROPE_THETA: f32 = 1e4;

    fn matvec_add(x: &[f32], w: &[f32], d_out: usize, y: &mut [f32]) {
        for (i, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &w[i * d_out..(i + 1) * d_out];
            for j in 0..d_out {
                y[j] += xv * row[j];
            }
        }
    }

    /// y += x·W through whatever representation the model holds for the
    /// site: dense slab, or B then C scalar products.
    fn apply(lin: &Linear<'_>, x: &[f32], y: &mut [f32]) {
        match lin {
            Linear::Dense { w, d2, .. } => matvec_add(x, w, *d2, y),
            Linear::Factored { b, c, .. } => {
                let mut mid = vec![0.0f32; b.cols];
                matvec_add(x, &b.data, b.cols, &mut mid);
                matvec_add(&mid, &c.data, c.cols, y);
            }
        }
    }

    fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
        let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for i in 0..x.len() {
            out[i] = x[i] * inv * w[i];
        }
    }

    fn rope_tables(t: usize, hd: usize) -> (Vec<f32>, Vec<f32>) {
        let half = hd / 2;
        let mut cos = vec![0.0f32; t * half];
        let mut sin = vec![0.0f32; t * half];
        for p in 0..t {
            for i in 0..half {
                let freq = ROPE_THETA.powf(-(i as f32) / half as f32);
                let ang = p as f32 * freq;
                cos[p * half + i] = ang.cos();
                sin[p * half + i] = ang.sin();
            }
        }
        (cos, sin)
    }

    fn apply_rope(v: &mut [f32], p: usize, cos: &[f32], sin: &[f32]) {
        let half = v.len() / 2;
        for i in 0..half {
            let c = cos[p * half + i];
            let s = sin[p * half + i];
            let x1 = v[i];
            let x2 = v[half + i];
            v[i] = x1 * c - x2 * s;
            v[half + i] = x2 * c + x1 * s;
        }
    }

    /// Full-prefix scalar forward; returns the logits predicting the
    /// token after `prefix` (the last position's row through the head).
    pub fn last_logits(m: &CompressedModel, prefix: &[i32]) -> Vec<f32> {
        let w = &m.base;
        let cfg = w.config;
        let (d, t) = (cfg.d, prefix.len());
        let embed = w.by_name("embed");
        let mut x = vec![0.0f32; t * d];
        for (pos, &tok) in prefix.iter().enumerate() {
            let tok = tok as usize;
            x[pos * d..(pos + 1) * d].copy_from_slice(&embed.data[tok * d..(tok + 1) * d]);
        }
        let (cos, sin) = rope_tables(t, cfg.head_dim());
        for l in 0..cfg.layers {
            attention_block(m, &mut x, t, l, &cos, &sin);
            mlp_block(m, &mut x, t, l);
        }
        let mut h = vec![0.0f32; d];
        rmsnorm(&x[(t - 1) * d..t * d], &w.by_name("final_norm").data, &mut h);
        let lm = w.by_name("lm_head");
        let mut logits = vec![0.0f32; cfg.vocab];
        matvec_add(&h, &lm.data, cfg.vocab, &mut logits);
        logits
    }

    fn attention_block(
        m: &CompressedModel,
        x: &mut [f32],
        t: usize,
        l: usize,
        cos: &[f32],
        sin: &[f32],
    ) {
        let w = &m.base;
        let cfg = w.config;
        let (d, h, kvh, hd) = (cfg.d, cfg.heads, cfg.kv_heads, cfg.head_dim());
        let kvd = cfg.kvd();
        let an = &w.by_name("attn_norm").data[l * d..(l + 1) * d];
        let rep = h / kvh;
        let scale = 1.0 / (hd as f32).sqrt();

        let mut xn = vec![0.0f32; d];
        let mut q = vec![0.0f32; t * d];
        let mut k = vec![0.0f32; t * kvd];
        let mut v = vec![0.0f32; t * kvd];
        for pos in 0..t {
            rmsnorm(&x[pos * d..(pos + 1) * d], an, &mut xn);
            apply(&m.linear("wq", l), &xn, &mut q[pos * d..(pos + 1) * d]);
            apply(&m.linear("wk", l), &xn, &mut k[pos * kvd..(pos + 1) * kvd]);
            apply(&m.linear("wv", l), &xn, &mut v[pos * kvd..(pos + 1) * kvd]);
            for head in 0..h {
                apply_rope(&mut q[pos * d + head * hd..pos * d + (head + 1) * hd], pos, cos, sin);
            }
            for head in 0..kvh {
                apply_rope(
                    &mut k[pos * kvd + head * hd..pos * kvd + (head + 1) * hd],
                    pos,
                    cos,
                    sin,
                );
            }
        }
        let mut attn = vec![0.0f32; t * d];
        let mut scores = vec![0.0f32; t];
        for head in 0..h {
            let kv_head = head / rep;
            for pos in 0..t {
                let qv = &q[pos * d + head * hd..pos * d + (head + 1) * hd];
                let mut max = f32::MIN;
                for j in 0..=pos {
                    let kv = &k[j * kvd + kv_head * hd..j * kvd + (kv_head + 1) * hd];
                    let s: f32 = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                    scores[j] = s;
                    max = max.max(s);
                }
                let mut denom = 0.0f32;
                for s in scores[..=pos].iter_mut() {
                    *s = (*s - max).exp();
                    denom += *s;
                }
                let out = &mut attn[pos * d + head * hd..pos * d + (head + 1) * hd];
                for j in 0..=pos {
                    let p = scores[j] / denom;
                    let vv = &v[j * kvd + kv_head * hd..j * kvd + (kv_head + 1) * hd];
                    for i in 0..hd {
                        out[i] += p * vv[i];
                    }
                }
            }
        }
        for pos in 0..t {
            let mut o = vec![0.0f32; d];
            apply(&m.linear("wo", l), &attn[pos * d..(pos + 1) * d], &mut o);
            let row = &mut x[pos * d..(pos + 1) * d];
            for i in 0..d {
                row[i] += o[i];
            }
        }
    }

    fn mlp_block(m: &CompressedModel, x: &mut [f32], t: usize, l: usize) {
        let w = &m.base;
        let cfg = w.config;
        let (d, dff) = (cfg.d, cfg.dff);
        let mn = &w.by_name("mlp_norm").data[l * d..(l + 1) * d];
        let mut xn = vec![0.0f32; d];
        for pos in 0..t {
            rmsnorm(&x[pos * d..(pos + 1) * d], mn, &mut xn);
            let mut g = vec![0.0f32; dff];
            let mut u = vec![0.0f32; dff];
            apply(&m.linear("w_gate", l), &xn, &mut g);
            apply(&m.linear("w_up", l), &xn, &mut u);
            for i in 0..dff {
                let s = g[i] / (1.0 + (-g[i]).exp());
                g[i] = s * u[i];
            }
            let mut o = vec![0.0f32; d];
            apply(&m.linear("w_down", l), &g, &mut o);
            let row = &mut x[pos * d..(pos + 1) * d];
            for i in 0..d {
                row[i] += o[i];
            }
        }
    }
}

// ------------------------------------------------- (a) prefill + k decodes

/// Prefill `start` tokens, then teacher-force the rest one decode step at
/// a time; after each step the logits must match the scalar full-prefix
/// oracle to 1e-5 (same check for the prefill logits themselves).
fn check_cached_path_against_oracle(m: &CompressedModel, start: usize, total: usize, seed: u64) {
    let cfg = m.config();
    let mut r = Rng::new(seed);
    let toks: Vec<i32> = (0..total).map(|_| r.below(cfg.vocab) as i32).collect();
    let mut state = fwd::DecodeState::new(&cfg, total);
    let mut logits = fwd::prefill_model(m, &toks[..start], &mut state);
    for fed in start..total {
        let want = oracle::last_logits(m, &toks[..fed]);
        assert_eq!(logits.len(), want.len());
        for (j, (g, o)) in logits.iter().zip(&want).enumerate() {
            assert!(
                (g - o).abs() < 1e-5,
                "prefix {fed}, logit {j}: cached {g} vs oracle {o}"
            );
        }
        logits = fwd::decode_step_model(m, toks[fed], &mut state);
    }
    assert_eq!(state.pos(), total);
}

#[test]
fn cached_decode_matches_scalar_oracle_dense_tiny() {
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, 3);
    // dense passthrough resolves every site to Linear::Dense — this is the
    // plain-weights decode path
    let m = CompressedModel::dense_passthrough(w.clone());
    check_cached_path_against_oracle(&m, 6, 14, 103);
    // and the raw-Weights entry points agree bitwise with the passthrough
    let toks: Vec<i32> = {
        let mut r = Rng::new(103);
        (0..14).map(|_| r.below(cfg.vocab) as i32).collect()
    };
    let mut sa = fwd::DecodeState::new(&cfg, 14);
    let mut sb = fwd::DecodeState::new(&cfg, 14);
    let la = fwd::prefill(&w, &toks[..6], &mut sa);
    let lb = fwd::prefill_model(&m, &toks[..6], &mut sb);
    assert_eq!(bits(&la), bits(&lb), "dense vs passthrough prefill");
    let da = fwd::decode_step(&w, toks[6], &mut sa);
    let db = fwd::decode_step_model(&m, toks[6], &mut sb);
    assert_eq!(bits(&da), bits(&db), "dense vs passthrough decode");
}

#[test]
fn cached_decode_matches_scalar_oracle_dense_gqa() {
    let cfg = ModelConfig::by_name("gqa").unwrap();
    let w = Weights::init(cfg, 4);
    let m = CompressedModel::dense_passthrough(w);
    check_cached_path_against_oracle(&m, 4, 11, 104);
}

#[test]
fn cached_decode_matches_scalar_oracle_factored_tiny() {
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, 5);
    let m = compress_drank(&w, 9);
    check_cached_path_against_oracle(&m, 6, 13, 105);
}

#[test]
fn cached_decode_matches_scalar_oracle_factored_gqa() {
    let cfg = ModelConfig::by_name("gqa").unwrap();
    let w = Weights::init(cfg, 6);
    let m = compress_drank(&w, 11);
    check_cached_path_against_oracle(&m, 5, 11, 106);
}

// -------------------------------------------------------- (b) determinism

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn decode_logits_bit_identical_across_thread_counts() {
    let _guard = THREAD_LOCK.lock().unwrap();
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, 7);
    let fact = compress_drank(&w, 13);
    let mut r = Rng::new(107);
    let total = 16usize;
    let toks: Vec<i32> = (0..total).map(|_| r.below(cfg.vocab) as i32).collect();

    // per-step fingerprints (prefill logits + every decode step's logits)
    let run = |threads: usize| -> (Vec<Vec<u32>>, Vec<Vec<u32>>) {
        set_threads(threads);
        let mut dense_fp = Vec::new();
        let mut fact_fp = Vec::new();
        let mut sd = fwd::DecodeState::new(&cfg, total);
        let mut sf = fwd::DecodeState::new(&cfg, total);
        dense_fp.push(bits(&fwd::prefill(&w, &toks[..8], &mut sd)));
        fact_fp.push(bits(&fwd::prefill_model(&fact, &toks[..8], &mut sf)));
        for &tok in &toks[8..] {
            dense_fp.push(bits(&fwd::decode_step(&w, tok, &mut sd)));
            fact_fp.push(bits(&fwd::decode_step_model(&fact, tok, &mut sf)));
        }
        (dense_fp, fact_fp)
    };
    let (d1, f1) = run(1);
    for t in [2usize, 4] {
        let (dt, ft) = run(t);
        assert_eq!(d1, dt, "dense prefill/decode differs at {t} threads");
        assert_eq!(f1, ft, "factored prefill/decode differs at {t} threads");
    }
    set_threads(0);
}

// ------------------------------------------------------------ (c) serving

#[test]
fn served_generate_matches_direct_loop_without_reconstruct() {
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, 8);
    let model = compress_drank(&w, 15);

    let prompt_len = 10usize;
    let max_new = 12usize;
    let mut r = Rng::new(108);
    let prompt_u32: Vec<u32> = (0..prompt_len).map(|_| r.below(cfg.vocab) as u32).collect();
    let prompt_i32: Vec<i32> = prompt_u32.iter().map(|&t| t as i32).collect();
    let opts = GenerateOpts { max_new_tokens: max_new, temperature: 0.0, seed: 0 };

    let before = profile::stage_calls(Stage::Reconstruct);
    let direct = fwd::generate_model(&model, &prompt_i32, &opts);
    assert_eq!(direct.len(), max_new);

    let server = spawn_model_server(
        model.clone(),
        cfg.batch,
        cfg.seq,
        "ref",
        ServerOpts { workers: 1, ..Default::default() },
    )
    .unwrap();
    let client = server.client();
    let resp = client.generate(prompt_u32, max_new).unwrap();
    assert_eq!(resp.tokens, direct, "served tokens diverge from the direct loop");
    assert!(resp.nll.is_empty(), "generate responses carry tokens, not NLLs");
    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.requests, 1);
    assert_eq!(metrics.generated_tokens, max_new);

    // factored weights decoded on their factors the whole way: the dense
    // matrices were never rematerialized, in-process or served
    let after = profile::stage_calls(Stage::Reconstruct);
    assert_eq!(after - before, 0, "decode path called Reconstruct");
}

#[test]
fn served_sampled_generate_is_seed_deterministic() {
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, 9);
    let model = CompressedModel::dense_passthrough(w.clone());
    let prompt: Vec<u32> = (1..=8).collect();

    let server = spawn_model_server(
        model,
        cfg.batch,
        cfg.seq,
        "ref",
        ServerOpts { workers: 2, ..Default::default() },
    )
    .unwrap();
    let client = server.client();
    let a = client.generate_sampled(prompt.clone(), 10, 0.8, 42).unwrap();
    let b = client.generate_sampled(prompt.clone(), 10, 0.8, 42).unwrap();
    assert_eq!(a.tokens, b.tokens, "same seed must replay the same stream");
    // and it is the same stream the in-process sampler draws
    let direct = fwd::generate(
        &w,
        &prompt.iter().map(|&t| t as i32).collect::<Vec<i32>>(),
        &GenerateOpts { max_new_tokens: 10, temperature: 0.8, seed: 42 },
    );
    assert_eq!(a.tokens, direct);
    server.shutdown().unwrap();
}
