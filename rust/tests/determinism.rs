//! Determinism suite: parallel compression output must be bit-identical to
//! a single-threaded run, across all six methods, both pipelines (plain
//! and §4.1 compensated), the blocked Jacobi eigensolver, the packed-panel
//! GEMM, and the blocked streaming-softmax serving forward. This is the
//! contract that lets `--threads N` be a pure speed knob — CI runs the
//! whole test suite under a 1/4-thread `DRANK_THREADS` matrix on top of
//! these explicit cross-count checks.
//!
//! The thread-pool size is process-global, so the tests that flip it hold a
//! lock to serialize against each other (results are thread-count invariant
//! by design, so concurrent *other* tests are unaffected either way).

use std::sync::Mutex;

use drank::calib::{CalibOpts, CalibStats};
use drank::compress::{methods, pipeline, CompressOpts, Method};
use drank::data::DataBundle;
use drank::linalg::eigen::jacobi_eigen_blocked;
use drank::model::lowrank::{CompressedModel, TypeRep};
use drank::model::{fwd, ModelConfig, Weights};
use drank::tensor::matmul::{gemm_f32, gemm_f32_packed, PackedMat};
use drank::tensor::MatF;
use drank::util::parallel::set_threads;
use drank::util::rng::Rng;

static THREAD_LOCK: Mutex<()> = Mutex::new(());

fn all_methods() -> [Method; 6] {
    [
        Method::PlainSvd,
        Method::Fwsvd,
        Method::Asvd,
        Method::SvdLlm,
        Method::BasisSharing,
        Method::DRank,
    ]
}

/// Exact bit pattern of every factor in the model (f32::to_bits — equality
/// means byte-identical factors, not "close").
fn fingerprint(m: &CompressedModel) -> Vec<u32> {
    let mut out = Vec::new();
    for rep in m.reps.values() {
        match rep {
            TypeRep::Dense => out.push(u32::MAX),
            TypeRep::Factored(groups) => {
                for g in groups {
                    out.push(g.start_layer as u32);
                    out.push(g.b.rows as u32);
                    out.push(g.b.cols as u32);
                    out.extend(g.b.data.iter().map(|x| x.to_bits()));
                    for c in &g.cs {
                        out.extend(c.data.iter().map(|x| x.to_bits()));
                    }
                }
            }
        }
    }
    out
}

#[test]
fn plain_pipeline_bit_identical_across_thread_counts() {
    let _guard = THREAD_LOCK.lock().unwrap();
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, 42);
    let stats = CalibStats::synthetic(&cfg, 7);
    for method in all_methods() {
        let opts = CompressOpts {
            method,
            ratio: 0.35,
            group_layers: 2,
            ..Default::default()
        };
        set_threads(1);
        let (m1, p1) = methods::compress(&w, &stats, &opts).unwrap();
        let f1 = fingerprint(&m1);
        for t in [2usize, 4] {
            set_threads(t);
            let (mt, pt) = methods::compress(&w, &stats, &opts).unwrap();
            assert_eq!(p1, pt, "{} rank plan diverged at {t} threads", method.name());
            assert_eq!(
                f1,
                fingerprint(&mt),
                "{} factors diverged at {t} threads",
                method.name()
            );
        }
    }
    set_threads(0);
}

#[test]
fn blocked_eigensolver_bit_identical_across_thread_counts() {
    let _guard = THREAD_LOCK.lock().unwrap();
    let mut rng = Rng::new(9);
    // sizes straddle the band/pair split boundaries: odd (tournament bye),
    // pool-sized, and larger-than-pool
    for n in [5usize, 33, 96] {
        let mut a = MatF::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let x = rng.normal();
                *a.at_mut(i, j) = x;
                *a.at_mut(j, i) = x;
            }
        }
        set_threads(1);
        let e1 = jacobi_eigen_blocked(&a);
        let vals1: Vec<u64> = e1.values.iter().map(|x| x.to_bits()).collect();
        let vecs1: Vec<u64> = e1.vectors.data.iter().map(|x| x.to_bits()).collect();
        for t in [2usize, 4] {
            set_threads(t);
            let et = jacobi_eigen_blocked(&a);
            let valst: Vec<u64> = et.values.iter().map(|x| x.to_bits()).collect();
            let vecst: Vec<u64> = et.vectors.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(vals1, valst, "eigenvalues diverged at {t} threads (n={n})");
            assert_eq!(vecs1, vecst, "eigenvectors diverged at {t} threads (n={n})");
        }
    }
    set_threads(0);
}

#[test]
fn packed_gemm_bit_identical_to_unpacked_across_thread_counts() {
    let _guard = THREAD_LOCK.lock().unwrap();
    let mut rng = Rng::new(23);
    // ragged shapes: partial final panel (130 % 64), sub-panel n, and a
    // k that straddles several BLOCK-sized k-blocks
    for (m, k, n) in [(65usize, 130usize, 33usize), (48, 37, 130), (96, 200, 64)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let bp = PackedMat::pack(&b, k, n);
        set_threads(1);
        let plain: Vec<u32> = gemm_f32(&a, m, k, &b, n).iter().map(|x| x.to_bits()).collect();
        let packed1: Vec<u32> =
            gemm_f32_packed(&a, m, k, &bp).iter().map(|x| x.to_bits()).collect();
        assert_eq!(plain, packed1, "packed != unpacked bits ({m}x{k}x{n})");
        for t in [2usize, 4] {
            set_threads(t);
            let packedt: Vec<u32> =
                gemm_f32_packed(&a, m, k, &bp).iter().map(|x| x.to_bits()).collect();
            assert_eq!(packed1, packedt, "packed gemm diverged at {t} threads ({m}x{k}x{n})");
        }
    }
    set_threads(0);
}

#[test]
fn streaming_attention_forward_bit_identical_across_thread_counts() {
    // the blocked streaming-softmax attention at sequence lengths that
    // span many ATTN_TQ/ATTN_TK tiles, plain and GQA: each output row's
    // FP order is fixed by the tile schedule, so thread count must be a
    // pure speed knob for the whole serving forward
    let _guard = THREAD_LOCK.lock().unwrap();
    let fingerprint = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    for (name, seed) in [("s", 29u64), ("gqa", 37u64)] {
        let cfg = ModelConfig::by_name(name).unwrap();
        let w = Weights::init(cfg, seed);
        let mut r = Rng::new(seed.wrapping_add(1));
        let (b, s) = (2usize, 96usize);
        let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
        set_threads(1);
        let f1 = fingerprint(&fwd::nll(&w, &toks, b, s));
        for t in [2usize, 4] {
            set_threads(t);
            let ft = fingerprint(&fwd::nll(&w, &toks, b, s));
            assert_eq!(f1, ft, "{name} forward diverged at {t} threads");
        }
    }
    set_threads(0);
}

#[test]
fn compensated_pipeline_bit_identical_across_thread_counts() {
    let _guard = THREAD_LOCK.lock().unwrap();
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, 42);
    let data = DataBundle::build(cfg.vocab, 3, 0.02);
    let copts = CalibOpts { batches: 2, ..Default::default() };
    // n=1 so the 2-layer tiny model exercises a real recalibration block;
    // this covers the parallel reference calibration path too
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.4,
        group_layers: 1,
        compensate: true,
        ..Default::default()
    };
    set_threads(1);
    let (m1, p1) = pipeline::compress_model_reference(&w, &data, &copts, &opts).unwrap();
    let f1 = fingerprint(&m1);
    for t in [2usize, 4] {
        set_threads(t);
        let (mt, pt) = pipeline::compress_model_reference(&w, &data, &copts, &opts).unwrap();
        assert_eq!(p1, pt, "compensated rank plan diverged at {t} threads");
        assert_eq!(f1, fingerprint(&mt), "compensated factors diverged at {t} threads");
    }
    set_threads(0);
}
