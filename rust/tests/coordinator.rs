//! Coordinator test suite over the pure-Rust reference backend.
//!
//! Everything here runs with **no** `artifacts/` directory and no PJRT —
//! the `ScoreBackend` seam lets the multi-worker server be pinned against
//! `model::fwd` directly: batch-window fill behavior, padding of short
//! requests, per-request NLL slice lengths, typed rejection
//! (`TooLong`/`QueueFull`/`Timeout`), drain-on-shutdown, worker scaling,
//! and the per-worker metric breakdowns.

use std::time::Duration;

use drank::coordinator::{RefBackend, ScoreBackend, ScoreError, Server, ServerOpts};
use drank::model::{fwd, ModelConfig, Weights};

const SEED: u64 = 42;

fn tiny() -> (ModelConfig, Weights) {
    let cfg = ModelConfig::by_name("tiny").unwrap();
    (cfg, Weights::init(cfg, SEED))
}

/// Reference-backend server over tiny weights; `tweak` adjusts the opts.
fn ref_server(workers: usize, tweak: impl FnOnce(&mut ServerOpts)) -> (ModelConfig, Server) {
    let (cfg, w) = tiny();
    let mut opts = ServerOpts { workers, ..Default::default() };
    tweak(&mut opts);
    let (b, s) = (cfg.batch, cfg.seq);
    let w = std::sync::Arc::new(w);
    let server = Server::spawn(move || Ok(RefBackend::shared(w.clone(), b, s)), opts);
    (cfg, server)
}

/// Deterministic slow backend: fixed service time per batch, zero NLL.
/// Sleep-based service makes the concurrency tests robust to machine load.
struct SlowBackend {
    delay: Duration,
    batch: usize,
    seq: usize,
}

impl ScoreBackend for SlowBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn nll(&self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        Ok(vec![0.5; (tokens.len() / self.seq) * (self.seq - 1)])
    }

    fn nll_window(&self, _tokens: &[i32], rows: usize, used_seq: usize) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        Ok(vec![0.5; rows * (used_seq - 1)])
    }
}

#[test]
fn responses_match_reference_forward() {
    let (cfg, server) = ref_server(2, |_| {});
    let mut rng = drank::util::rng::Rng::new(7);
    let rows: Vec<Vec<u32>> = (0..6)
        .map(|_| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect())
        .collect();
    let handles: Vec<_> = rows
        .iter()
        .cloned()
        .map(|r| {
            let c = server.client();
            std::thread::spawn(move || c.score(r).unwrap())
        })
        .collect();
    let resps: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let w = Weights::init(cfg, SEED);
    for (row, resp) in rows.iter().zip(&resps) {
        assert_eq!(resp.nll.len(), cfg.seq - 1);
        let toks: Vec<i32> = row.iter().map(|&t| t as i32).collect();
        let want = fwd::nll(&w, &toks, 1, cfg.seq);
        for (a, b) in resp.nll.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "server vs direct forward: {a} vs {b}");
        }
        assert!(resp.worker < 2);
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests, 6);
    assert_eq!(m.tokens, 6 * cfg.seq);
}

#[test]
fn short_requests_are_zero_padded() {
    let (cfg, server) = ref_server(1, |_| {});
    let client = server.client();
    let len = cfg.seq / 2;
    let toks: Vec<u32> = (1..=len as u32).collect();
    let resp = client.score(toks.clone()).unwrap();
    // the NLL slice covers only the request's own tokens
    assert_eq!(resp.nll.len(), len - 1);
    // and matches the reference forward over the zero-padded row
    let mut padded = vec![0i32; cfg.seq];
    for (i, &t) in toks.iter().enumerate() {
        padded[i] = t as i32;
    }
    let w = Weights::init(cfg, SEED);
    let want = fwd::nll(&w, &padded, 1, cfg.seq);
    for i in 0..len - 1 {
        assert!((resp.nll[i] - want[i]).abs() < 1e-5, "position {i}");
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.tokens, len);
    // the executed window shrank to the request's own length: no waste
    assert_eq!(m.padded_tokens, len);
    assert!((m.padding_efficiency() - 1.0).abs() < 1e-9);
}

#[test]
fn mixed_length_batch_pads_to_longest() {
    let (_cfg, server) = ref_server(1, |o| {
        o.batch_window = Duration::from_millis(150);
        o.bucket_by_length = false;
    });
    let lens = [4usize, 16];
    let handles: Vec<_> = lens
        .iter()
        .map(|&len| {
            let c = server.client();
            std::thread::spawn(move || c.score(vec![1; len]).unwrap())
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests, 2);
    assert_eq!(m.tokens, 20);
    if m.batches == 1 {
        // one batch: both rows padded to the longest request
        assert_eq!(m.padded_tokens, 2 * 16);
        assert!((m.padding_efficiency() - 20.0 / 32.0).abs() < 1e-9);
    } else {
        // scheduling split them: each window shrank to its own request
        assert_eq!(m.padded_tokens, m.tokens);
    }
}

#[test]
fn per_request_nll_slice_lengths() {
    let (cfg, server) = ref_server(1, |o| o.batch_window = Duration::from_millis(20));
    let lens = [2usize, 3, cfg.seq / 2, cfg.seq];
    let handles: Vec<_> = lens
        .iter()
        .map(|&len| {
            let c = server.client();
            std::thread::spawn(move || (len, c.score(vec![1; len]).unwrap()))
        })
        .collect();
    for h in handles {
        let (len, resp) = h.join().unwrap();
        assert_eq!(resp.nll.len(), len - 1, "request of {len} tokens");
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests, 4);
    assert_eq!(m.tokens, lens.iter().sum::<usize>());
    assert!(m.padded_tokens >= m.tokens);
}

#[test]
fn overlength_requests_rejected_not_truncated() {
    // regression: the old worker clipped over-length requests with
    // take(seq) and billed min(len, seq) tokens — now a typed rejection
    let (cfg, server) = ref_server(1, |_| {});
    let client = server.client();
    match client.score(vec![1; cfg.seq + 5]) {
        Err(ScoreError::TooLong { len, seq }) => {
            assert_eq!(len, cfg.seq + 5);
            assert_eq!(seq, cfg.seq);
        }
        other => panic!("expected TooLong, got {other:?}"),
    }
    // the worker keeps serving after a rejection
    let ok = client.score(vec![1, 2, 3]).unwrap();
    assert_eq!(ok.nll.len(), 2);
    let m = server.shutdown().unwrap();
    assert_eq!(m.rejected_too_long, 1);
    assert_eq!(m.requests, 1); // the rejected request was never billed
    assert_eq!(m.tokens, 3);
}

#[test]
fn out_of_vocab_token_rejected_per_request() {
    // regression: an out-of-range token id must produce a typed rejection,
    // not panic the worker (which would take the whole server down)
    let (cfg, server) = ref_server(1, |_| {});
    let client = server.client();
    let bad = cfg.vocab as u32 + 7;
    match client.score(vec![1, bad, 3]) {
        Err(ScoreError::InvalidToken { id, vocab }) => {
            assert_eq!(id, bad);
            assert_eq!(vocab, cfg.vocab);
        }
        other => panic!("expected InvalidToken, got {other:?}"),
    }
    // the server keeps serving after the rejection
    let ok = client.score(vec![1, 2, 3]).unwrap();
    assert_eq!(ok.nll.len(), 2);
    let m = server.shutdown().unwrap();
    assert_eq!(m.rejected_invalid_token, 1);
    assert_eq!(m.rejected(), 1);
    assert_eq!(m.requests, 1);
}

#[test]
fn try_score_rejects_when_queue_saturated() {
    let opts = ServerOpts {
        workers: 1,
        queue: 1,
        batch_window: Duration::from_millis(0),
        ..Default::default()
    };
    let server = Server::spawn(
        move || Ok(SlowBackend { delay: Duration::from_millis(300), batch: 1, seq: 8 }),
        opts,
    );
    let c1 = server.client();
    let h1 = std::thread::spawn(move || c1.score(vec![1, 2, 3]).unwrap());
    std::thread::sleep(Duration::from_millis(100)); // worker now inside the backend
    let c2 = server.client();
    let h2 = std::thread::spawn(move || c2.score(vec![1, 2, 3]).unwrap());
    std::thread::sleep(Duration::from_millis(50)); // second request fills the 1-slot queue
    let c3 = server.client();
    match c3.try_score(vec![1, 2, 3]) {
        Err(ScoreError::QueueFull) => {}
        other => panic!("expected QueueFull, got {other:?}"),
    }
    h1.join().unwrap();
    h2.join().unwrap();
    let m = server.shutdown().unwrap();
    assert_eq!(m.rejected_queue_full, 1);
    assert_eq!(m.requests, 2);
}

#[test]
fn queued_deadline_produces_timeout() {
    let opts = ServerOpts {
        workers: 1,
        batch_window: Duration::from_millis(0),
        deadline: Some(Duration::from_millis(40)),
        ..Default::default()
    };
    let server = Server::spawn(
        move || Ok(SlowBackend { delay: Duration::from_millis(150), batch: 1, seq: 8 }),
        opts,
    );
    let c1 = server.client();
    let h1 = std::thread::spawn(move || c1.score(vec![1, 2]).unwrap());
    std::thread::sleep(Duration::from_millis(30)); // worker busy for ~150ms
    let c2 = server.client();
    let h2 = std::thread::spawn(move || c2.score(vec![1, 2]));
    h1.join().unwrap();
    let r2 = h2.join().unwrap();
    assert!(matches!(r2, Err(ScoreError::Timeout)), "got {r2:?}");
    let m = server.shutdown().unwrap();
    assert_eq!(m.rejected_timeout, 1);
    assert_eq!(m.requests, 1);
}

#[test]
fn shutdown_drains_in_flight_requests() {
    let opts = ServerOpts {
        workers: 1,
        queue: 16,
        batch_window: Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::spawn(
        move || Ok(SlowBackend { delay: Duration::from_millis(50), batch: 2, seq: 8 }),
        opts,
    );
    let mut handles = Vec::new();
    for i in 0..6u32 {
        let c = server.client();
        handles.push(std::thread::spawn(move || c.score(vec![1 + i, 2, 3]).unwrap()));
    }
    std::thread::sleep(Duration::from_millis(120)); // backlog queued, worker mid-batch
    let m = server.shutdown().unwrap(); // must drain before joining
    assert_eq!(m.requests, 6, "shutdown dropped queued requests");
    for h in handles {
        h.join().unwrap(); // every client got a response
    }
}

#[test]
fn batch_window_fills_batches() {
    let (cfg, server) = ref_server(1, |o| o.batch_window = Duration::from_millis(100));
    let handles: Vec<_> = (0..6)
        .map(|_| {
            let c = server.client();
            let seq = cfg.seq;
            std::thread::spawn(move || c.score(vec![1; seq]).unwrap())
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests, 6);
    // with a 100ms window and batch capacity 2, batching must have happened
    assert!(m.batches < m.requests, "no batching: {} batches", m.batches);
    assert!(m.mean_batch_occupancy() > 1.0);
    // full-length requests waste no padding
    assert_eq!(m.tokens, 6 * cfg.seq);
    assert_eq!(m.padded_tokens, m.tokens);
    assert!((m.padding_efficiency() - 1.0).abs() < 1e-9);
}

#[test]
fn per_worker_metrics_are_consistent() {
    let (cfg, server) = ref_server(2, |o| o.batch_window = Duration::from_millis(10));
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let c = server.client();
            let seq = cfg.seq;
            std::thread::spawn(move || c.score(vec![2; seq]).unwrap())
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.per_worker.len(), 2);
    assert_eq!(m.per_worker.iter().map(|w| w.requests).sum::<usize>(), m.requests);
    assert_eq!(m.per_worker.iter().map(|w| w.batches).sum::<usize>(), m.batches);
    assert_eq!(m.per_worker.iter().map(|w| w.tokens).sum::<usize>(), m.tokens);
    assert_eq!(m.queue_depth_samples, m.batches);
    assert!(m.mean_queue_depth() >= 0.0);
    assert!(m.utilization() > 0.0);
}

#[test]
fn two_workers_outscale_one() {
    // sleep-based service time makes scaling deterministic: with one
    // worker 8 requests serialize (~8 * 30ms); with two they overlap
    fn run(workers: usize) -> f64 {
        let opts = ServerOpts {
            workers,
            queue: 64,
            batch_window: Duration::from_millis(0),
            ..Default::default()
        };
        let server = Server::spawn(
            move || Ok(SlowBackend { delay: Duration::from_millis(30), batch: 1, seq: 8 }),
            opts,
        );
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = server.client();
                std::thread::spawn(move || c.score(vec![1, 2, 3, 4]).unwrap())
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let m = server.shutdown().unwrap();
        assert_eq!(m.requests, 8);
        m.throughput_tps()
    }
    let t1 = run(1);
    let t2 = run(2);
    assert!(
        t2 > t1 * 1.3,
        "2 workers ({t2:.0} tok/s) should outscale 1 worker ({t1:.0} tok/s)"
    );
}

/// Panics on every scoring call (a poisoned batch, an indexing bug, ...)
/// after a short delay, so other requests can queue up behind the batch.
struct PanicBackend;

impl ScoreBackend for PanicBackend {
    fn batch(&self) -> usize {
        1
    }
    fn seq(&self) -> usize {
        8
    }
    fn nll(&self, _tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(Duration::from_millis(80));
        panic!("backend exploded")
    }
}

#[test]
fn worker_panic_does_not_hang_clients() {
    let server = Server::spawn(
        move || Ok(PanicBackend),
        ServerOpts { workers: 1, ..Default::default() },
    );
    // first request: the worker panics executing it (reply channel drops)
    let c1 = server.client();
    let h1 = std::thread::spawn(move || c1.score(vec![1, 2, 3]));
    std::thread::sleep(Duration::from_millis(30)); // worker inside the backend
    // second request queues *behind* the doomed batch: the unwinding
    // worker's guard must drain it, not strand its client in recv()
    let c2 = server.client();
    let h2 = std::thread::spawn(move || c2.score(vec![4, 5, 6]));
    let r1 = h1.join().unwrap();
    assert!(r1.is_err(), "got {r1:?}");
    let r2 = h2.join().unwrap();
    assert!(matches!(r2, Err(ScoreError::Shutdown)), "got {r2:?}");
    // the guard also closed the queue: later calls fail fast
    std::thread::sleep(Duration::from_millis(50));
    let r3 = server.client().score(vec![1, 2, 3]);
    assert!(matches!(r3, Err(ScoreError::Shutdown)), "got {r3:?}");
    // and shutdown reports an error instead of re-panicking
    assert!(server.shutdown().is_err());
}

#[test]
fn backend_construction_failure_fails_cleanly() {
    let opts = ServerOpts { workers: 1, ..Default::default() };
    let server = Server::spawn(|| Err::<RefBackend, _>(anyhow::anyhow!("boom")), opts);
    let client = server.client();
    // no hang: the request is either drained with a Backend error or
    // rejected because the failed worker closed the queue
    let res = client.score(vec![1, 2, 3]);
    assert!(res.is_err(), "got {res:?}");
    let err = server.shutdown().unwrap_err();
    assert!(format!("{err}").contains("boom"));
}

/// Records the process-wide pool size the backend sees while scoring —
/// the observable effect of `ServerOpts::threads`.
struct PoolProbeBackend {
    seen: std::sync::Arc<std::sync::Mutex<Vec<usize>>>,
}

impl ScoreBackend for PoolProbeBackend {
    fn batch(&self) -> usize {
        1
    }

    fn seq(&self) -> usize {
        8
    }

    fn nll(&self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        self.seen.lock().unwrap().push(drank::util::parallel::threads());
        Ok(vec![0.0; (tokens.len() / 8) * 7])
    }
}

#[test]
fn factored_serving_never_reconstructs_dense_weights() {
    // Acceptance: `drank serve --backend ref` on a compressed model must
    // serve the factors directly — the Reconstruct stage stays flat while
    // fwd_lowrank climbs. This assertion lives HERE (and not in the lib
    // unit tests) because profile counters are process-global: this binary
    // contains no other test that reconstructs dense weights, so the delta
    // is race-free even under the default parallel test runner.
    use drank::calib::CalibStats;
    use drank::compress::{methods, CompressOpts, Method};
    use drank::util::profile::{stage_calls, Stage};

    let (cfg, w) = tiny();
    let stats = CalibStats::synthetic(&cfg, 5);
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.3,
        group_layers: 2,
        ..Default::default()
    };
    let (model, _) = methods::compress(&w, &stats, &opts).unwrap();
    assert!(model.achieved_ratio() > 0.0, "compression was a no-op; test is vacuous");

    let recon0 = stage_calls(Stage::Reconstruct);
    let lowrank0 = stage_calls(Stage::FwdLowrank);
    let server = drank::coordinator::spawn_model_server(
        model,
        cfg.batch,
        cfg.seq,
        "ref",
        ServerOpts { workers: 2, ..Default::default() },
    )
    .unwrap();
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let c = server.client();
            let seq = cfg.seq;
            std::thread::spawn(move || c.score(vec![1 + i as u32; seq]).unwrap())
        })
        .collect();
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.nll.len(), cfg.seq - 1);
        assert!(resp.nll.iter().all(|x| x.is_finite()));
    }
    let m = server.shutdown().unwrap();
    assert_eq!(m.requests, 6);
    assert_eq!(
        stage_calls(Stage::Reconstruct),
        recon0,
        "factored ref serving rematerialized dense weights"
    );
    assert!(
        stage_calls(Stage::FwdLowrank) > lowrank0,
        "factored ref serving never ran a low-rank projection"
    );
}

#[test]
fn server_opts_threads_sizes_the_shared_pool() {
    // `threads` rides the same process-global knob as `--threads` on the
    // compression side: ServerOpts::threads > 0 must be what the scoring
    // backends observe, and the default (0) must leave the setting alone.
    assert_eq!(ServerOpts::default().threads, 0, "default must not resize the pool");
    let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    let opts = ServerOpts {
        workers: 1,
        threads: 3,
        batch_window: Duration::from_millis(0),
        ..Default::default()
    };
    let probe = seen.clone();
    let server = Server::spawn(move || Ok(PoolProbeBackend { seen: probe.clone() }), opts);
    server.client().score(vec![1, 2, 3, 4]).unwrap();
    server.shutdown().unwrap();
    assert_eq!(*seen.lock().unwrap(), vec![3], "backend saw a differently sized pool");
    // restore the default so later tests in this binary see a clean pool
    drank::util::parallel::set_threads(0);
    assert!(drank::util::parallel::threads() >= 1);
}
