//! Pack-once and zero-alloc contracts of the packed-panel serving path.
//!
//! The serving forward packs every weight slab into block-major panels
//! (`tensor::matmul::PackedMat`) lazily, exactly once per projection site,
//! and the fused factored path `(x·B)·C` reuses one per-thread scratch
//! buffer for the intermediate. Both contracts are observable only through
//! process-global counters (`pack_ops`, `scratch_grows`), so this suite is
//! its own test binary: no other crate tests run in this process to bump
//! the counters concurrently, and a local lock serializes the tests here.

use std::sync::Mutex;

use drank::calib::CalibStats;
use drank::compress::{methods, CompressOpts, Method};
use drank::model::lowrank::{self, CompressedModel, TypeRep};
use drank::model::{fwd, ModelConfig, Weights, COMPRESSIBLE};
use drank::tensor::matmul::pack_ops;
use drank::util::parallel::set_threads;
use drank::util::rng::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn tiny_setup(seed: u64) -> (ModelConfig, Weights, Vec<i32>) {
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, seed);
    let mut r = Rng::new(seed.wrapping_add(50));
    let toks: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|_| r.below(cfg.vocab) as i32).collect();
    (cfg, w, toks)
}

fn tiny_factored(seed: u64) -> (ModelConfig, CompressedModel, Vec<i32>) {
    let (cfg, w, toks) = tiny_setup(seed);
    let stats = CalibStats::synthetic(&cfg, seed.wrapping_add(7));
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.3,
        group_layers: 2,
        ..Default::default()
    };
    let (model, _) = methods::compress(&w, &stats, &opts).unwrap();
    assert!(model.achieved_ratio() > 0.0, "no compression — tests would be vacuous");
    (cfg, model, toks)
}

/// Pack slots one full forward must initialize: per compressible type,
/// one panel per dense layer, plus one shared-basis panel per group and
/// one coefficient panel per covered layer; plus the lm_head.
fn expected_packs(cfg: &ModelConfig, model: &CompressedModel) -> usize {
    let mut expect = 1usize; // lm_head
    for typ in COMPRESSIBLE {
        match &model.reps[typ] {
            TypeRep::Dense => expect += cfg.layers,
            TypeRep::Factored(groups) => {
                let covered: usize = groups.iter().map(|g| g.n_layers()).sum();
                expect += groups.len() + covered + (cfg.layers - covered);
            }
        }
    }
    expect
}

#[test]
fn dense_weights_pack_each_site_exactly_once() {
    let _g = LOCK.lock().unwrap();
    let (cfg, w, toks) = tiny_setup(41);
    assert_eq!(w.packs.packed_sites(), 0);
    let p0 = pack_ops();
    let first = fwd::nll(&w, &toks, cfg.batch, cfg.seq);
    // 7 compressible types × layers, plus lm_head
    let sites = COMPRESSIBLE.len() * cfg.layers + 1;
    assert_eq!(pack_ops() - p0, sites as u64, "first forward packs every site once");
    assert_eq!(w.packs.packed_sites(), sites);
    // steady state: no re-packing, identical output bits
    let p1 = pack_ops();
    for _ in 0..3 {
        let again = fwd::nll(&w, &toks, cfg.batch, cfg.seq);
        assert_eq!(
            again.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            first.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
    assert_eq!(pack_ops(), p1, "repeat forwards must not re-pack");
    assert_eq!(w.packs.packed_sites(), sites);
}

#[test]
fn factored_model_packs_each_site_exactly_once_and_shares_group_bases() {
    let _g = LOCK.lock().unwrap();
    let (cfg, model, toks) = tiny_factored(43);
    assert_eq!(model.packed_sites(), 0);
    let expect = expected_packs(&cfg, &model);
    let p0 = pack_ops();
    let _ = fwd::nll_model(&model, &toks, cfg.batch, cfg.seq);
    assert_eq!(pack_ops() - p0, expect as u64, "factored forward packs every site once");
    assert_eq!(model.packed_sites(), expect);
    // a shared basis is one slot per *group*, so a multi-layer group packs
    // strictly fewer panels than two per covered layer
    let dense_upper = 2 * COMPRESSIBLE.len() * cfg.layers + 1;
    assert!(expect < dense_upper, "group bases not shared: {expect} >= {dense_upper}");
    let p1 = pack_ops();
    let _ = fwd::nll_model(&model, &toks, cfg.batch, cfg.seq);
    assert_eq!(pack_ops(), p1, "repeat factored forwards must not re-pack");
}

#[test]
fn pack_cache_survives_thread_count_changes_but_not_clones() {
    let _g = LOCK.lock().unwrap();
    let (cfg, w, toks) = tiny_setup(47);
    set_threads(1);
    let _ = fwd::nll(&w, &toks, cfg.batch, cfg.seq);
    let p0 = pack_ops();
    set_threads(4);
    let _ = fwd::nll(&w, &toks, cfg.batch, cfg.seq);
    set_threads(0);
    assert_eq!(pack_ops(), p0, "thread-count change must not re-pack");
    // clones are for mutation: they start with an empty cache
    let w2 = w.clone();
    assert!(w.packs.packed_sites() > 0);
    assert_eq!(w2.packs.packed_sites(), 0, "clone must reset the pack cache");
    let (_, model, mtoks) = tiny_factored(48);
    let _ = fwd::nll_model(&model, &mtoks, cfg.batch, cfg.seq);
    assert!(model.packed_sites() > 0);
    assert_eq!(model.clone().packed_sites(), 0, "model clone must reset pack caches");
    // to_dense clones the base, so its registry is empty too — its tensors
    // are about to be overwritten with reconstructions
    assert_eq!(model.to_dense().packs.packed_sites(), 0);
}

#[test]
fn fused_factored_path_reuses_scratch_with_zero_per_call_growth() {
    let _g = LOCK.lock().unwrap();
    let (cfg, model, toks) = tiny_factored(53);
    // warmup: packs panels and grows this thread's scratch to its
    // steady-state size
    let first = fwd::nll_model(&model, &toks, cfg.batch, cfg.seq);
    let g0 = lowrank::scratch_grows();
    for _ in 0..3 {
        let again = fwd::nll_model(&model, &toks, cfg.batch, cfg.seq);
        assert_eq!(
            again.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            first.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
    assert_eq!(
        lowrank::scratch_grows(),
        g0,
        "steady-state factored serving must not grow the intermediate scratch"
    );
}
