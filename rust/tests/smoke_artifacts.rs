//! Smoke: every tiny artifact must parse, compile and execute via PJRT.
//! Gated on PJRT + artifact availability — skips with a message on a bare
//! checkout (no `artifacts/`, or the offline xla stub).
use anyhow::Result;

fn lit(shape: &[usize]) -> xla::Literal {
    let n: usize = shape.iter().product();
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&vec![0.01f32; n]).reshape(&dims).unwrap()
}

/// PJRT runtime + the named artifact file, or skip.
fn rt_or_skip(test: &str, artifact: &str) -> Option<drank::runtime::Runtime> {
    if !std::path::Path::new(artifact).exists() {
        eprintln!("skipping {test}: {artifact} not found — run `make artifacts`");
        return None;
    }
    match drank::runtime::Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping {test}: PJRT unavailable ({e})");
            None
        }
    }
}

#[test]
fn tiny_dense_nll_roundtrip() -> Result<()> {
    let Some(rt) = rt_or_skip("tiny_dense_nll_roundtrip", "artifacts/tiny_dense_nll.hlo.txt")
    else {
        return Ok(());
    };
    let exe = rt.load_hlo_text("artifacts/tiny_dense_nll.hlo.txt")?;
    // tiny: V=256 d=64 L=2 H=4 KVH=4 dff=176 S=64 B=2
    let (v, d, l, dff, s, b) = (256, 64, 2, 176, 64, 2);
    let mut inputs = vec![
        lit(&[v, d]),
        lit(&[l, d]),
        lit(&[l, d, d]),
        lit(&[l, d, 64]),
        lit(&[l, d, 64]),
        lit(&[l, d, d]),
        lit(&[l, d]),
        lit(&[l, d, dff]),
        lit(&[l, d, dff]),
        lit(&[l, dff, d]),
        lit(&[d]),
        lit(&[d, v]),
    ];
    let toks: Vec<i32> = (0..(b * s) as i32).map(|i| i % 256).collect();
    inputs.push(xla::Literal::vec1(&toks).reshape(&[b as i64, s as i64])?);
    let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
    let out = result.to_tuple1()?;
    let nll = out.to_vec::<f32>()?;
    assert_eq!(nll.len(), b * (s - 1));
    assert!(nll.iter().all(|x| x.is_finite()));
    Ok(())
}

#[test]
fn tiny_train_step_roundtrip() -> Result<()> {
    let Some(rt) = rt_or_skip("tiny_train_step_roundtrip", "artifacts/tiny_train_step.hlo.txt")
    else {
        return Ok(());
    };
    let exe = rt.load_hlo_text("artifacts/tiny_train_step.hlo.txt")?;
    let (v, d, l, dff, s, b) = (256, 64, 2, 176, 64, 2);
    let pshapes: Vec<Vec<usize>> = vec![
        vec![v, d],
        vec![l, d],
        vec![l, d, d],
        vec![l, d, 64],
        vec![l, d, 64],
        vec![l, d, d],
        vec![l, d],
        vec![l, d, dff],
        vec![l, d, dff],
        vec![l, dff, d],
        vec![d],
        vec![d, v],
    ];
    let mut inputs: Vec<xla::Literal> = Vec::new();
    for _ in 0..3 {
        for sh in &pshapes {
            inputs.push(lit(sh));
        }
    }
    inputs.push(xla::Literal::scalar(1.0f32)); // step
    inputs.push(xla::Literal::scalar(1e-3f32)); // lr
    let toks: Vec<i32> = (0..(b * s) as i32).map(|i| i % 256).collect();
    inputs.push(xla::Literal::vec1(&toks).reshape(&[b as i64, s as i64])?);
    let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
    let outs = result.to_tuple()?;
    assert_eq!(outs.len(), 37);
    let loss: f32 = outs[0].get_first_element()?;
    assert!(loss.is_finite() && loss > 0.0);
    Ok(())
}
