//! Cross-layer integration tests.
//!
//! The same model semantics are implemented three times (JAX → AOT HLO
//! artifact, pure-Rust reference, runtime XlaBuilder graph); these tests
//! pin all three to each other, then exercise the full compression →
//! evaluation pipeline end to end on the tiny config.
//!
//! Everything here needs PJRT plus the `artifacts/` directory from
//! `make artifacts`, so each test gates on engine availability and skips
//! with a message on a bare checkout (InfiniLM-style NotFound => return).
//! The artifact-free counterparts live in `coordinator.rs`/`pipeline.rs`.

use drank::calib::{CalibOpts, CalibStats};
use drank::compress::{methods, CompressOpts, Method};
use drank::data::DataBundle;
use drank::graph;
use drank::model::{fwd, ModelConfig, Weights};
use drank::runtime::{lit_i32, Engine};
use drank::util::rng::Rng;

/// PJRT + artifacts, or skip the test with a visible message.
fn engine_or_skip(test: &str) -> Option<Engine> {
    match Engine::open("artifacts") {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping {test}: PJRT artifacts unavailable ({e})");
            None
        }
    }
}

fn tiny_setup() -> (ModelConfig, Weights, Vec<i32>) {
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, 42);
    let mut r = Rng::new(7);
    let toks: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|_| r.below(cfg.vocab) as i32)
        .collect();
    (cfg, w, toks)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn artifact_matches_pure_rust_forward() {
    let Some(engine) = engine_or_skip("artifact_matches_pure_rust_forward") else {
        return;
    };
    let (cfg, w, toks) = tiny_setup();
    engine.check_config(&cfg).unwrap();
    let mut inputs = engine.weight_literals(&w).unwrap();
    inputs.push(lit_i32(&toks, &[cfg.batch, cfg.seq]).unwrap());
    let outs = engine.exec(cfg.name, "dense_nll", &inputs).unwrap();
    let artifact_nll = outs[0].to_vec::<f32>().unwrap();

    let rust_nll = fwd::nll(&w, &toks, cfg.batch, cfg.seq);
    assert_eq!(artifact_nll.len(), rust_nll.len());
    let d = max_abs_diff(&artifact_nll, &rust_nll);
    assert!(d < 2e-3, "artifact vs rust fwd: max diff {d}");
}

#[test]
fn runtime_graph_matches_artifact() {
    let Some(engine) = engine_or_skip("runtime_graph_matches_artifact") else {
        return;
    };
    let (cfg, w, toks) = tiny_setup();
    let mut inputs = engine.weight_literals(&w).unwrap();
    inputs.push(lit_i32(&toks, &[cfg.batch, cfg.seq]).unwrap());
    let outs = engine.exec(cfg.name, "dense_nll", &inputs).unwrap();
    let artifact_nll = outs[0].to_vec::<f32>().unwrap();

    let compiled = graph::compile_dense(&engine.rt, &w, cfg.batch, cfg.seq).unwrap();
    let graph_nll = compiled.nll(&toks).unwrap();
    let d = max_abs_diff(&artifact_nll, &graph_nll);
    assert!(d < 2e-3, "graph vs artifact: max diff {d}");
}

#[test]
fn compressed_graph_matches_reconstructed_dense() {
    // factored execution (x·B·C) must equal executing the reconstruction
    let Some(engine) = engine_or_skip("compressed_graph_matches_reconstructed_dense") else {
        return;
    };
    let (cfg, w, toks) = tiny_setup();
    let stats = CalibStats::synthetic(&cfg, 5);
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.3,
        group_layers: 2,
        ..Default::default()
    };
    let (model, _) = methods::compress(&w, &stats, &opts).unwrap();
    assert!(model.achieved_ratio() > 0.25);

    let compiled = graph::compile_forward(&engine.rt, &model, cfg.batch, cfg.seq).unwrap();
    let factored_nll = compiled.nll(&toks).unwrap();

    let dense = model.to_dense();
    let mut inputs = engine.weight_literals(&dense).unwrap();
    inputs.push(lit_i32(&toks, &[cfg.batch, cfg.seq]).unwrap());
    let outs = engine.exec(cfg.name, "dense_nll", &inputs).unwrap();
    let dense_nll = outs[0].to_vec::<f32>().unwrap();

    let d = max_abs_diff(&factored_nll, &dense_nll);
    assert!(d < 5e-3, "factored vs reconstructed: max diff {d}");
}

#[test]
fn gqa_graph_matches_pure_rust() {
    let Some(engine) = engine_or_skip("gqa_graph_matches_pure_rust") else {
        return;
    };
    let cfg = ModelConfig::by_name("gqa").unwrap();
    let w = Weights::init(cfg, 9);
    let mut r = Rng::new(8);
    let toks: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|_| r.below(cfg.vocab) as i32)
        .collect();
    let compiled = graph::compile_dense(&engine.rt, &w, cfg.batch, cfg.seq).unwrap();
    let graph_nll = compiled.nll(&toks).unwrap();
    let rust_nll = fwd::nll(&w, &toks, cfg.batch, cfg.seq);
    let d = max_abs_diff(&graph_nll, &rust_nll);
    assert!(d < 2e-3, "gqa graph vs rust: max diff {d}");
}

#[test]
fn calibration_gram_is_symmetric_psd() {
    let Some(engine) = engine_or_skip("calibration_gram_is_symmetric_psd") else {
        return;
    };
    let (cfg, w, _) = tiny_setup();
    let data = DataBundle::build(cfg.vocab, 3, 0.02);
    let copts = CalibOpts { batches: 2, ..Default::default() };
    let stats = drank::calib::run(&engine, &w, &data, &copts).unwrap();
    let g = stats.gram("wq", 0);
    assert_eq!(g.rows, cfg.d);
    for i in 0..cfg.d {
        for j in 0..cfg.d {
            assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-4);
        }
    }
    let diag_mean: f64 = (0..cfg.d).map(|i| g.at(i, i)).sum::<f64>() / cfg.d as f64;
    assert!(diag_mean > 0.0);
    // fisher off by default
    assert!(stats.fisher_rows("wq", 0).is_none());
}

#[test]
fn coordinator_serves_correct_nll() {
    // server responses must match a direct artifact evaluation
    let Some(engine) = engine_or_skip("coordinator_serves_correct_nll") else {
        return;
    };
    let (cfg, w, toks) = tiny_setup();
    let mut inputs = engine.weight_literals(&w).unwrap();
    inputs.push(lit_i32(&toks, &[cfg.batch, cfg.seq]).unwrap());
    let outs = engine.exec(cfg.name, "dense_nll", &inputs).unwrap();
    let want = outs[0].to_vec::<f32>().unwrap();
    drop(engine);

    let w2 = w.clone();
    let server = drank::coordinator::Server::spawn(
        move || {
            let rt = drank::runtime::Runtime::cpu()?;
            graph::compile_dense(&rt, &w2, cfg.batch, cfg.seq)
        },
        drank::coordinator::ServerOpts::default(),
    );
    // submit each row as a separate request from separate threads
    let mut handles = Vec::new();
    for r in 0..cfg.batch {
        let client = server.client();
        let row: Vec<u32> = toks[r * cfg.seq..(r + 1) * cfg.seq]
            .iter()
            .map(|&t| t as u32)
            .collect();
        handles.push(std::thread::spawn(move || client.score(row).unwrap()));
    }
    let responses: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (r, resp) in responses.iter().enumerate() {
        assert_eq!(resp.nll.len(), cfg.seq - 1);
        let row_want = &want[r * (cfg.seq - 1)..(r + 1) * (cfg.seq - 1)];
        let d = max_abs_diff(&resp.nll, row_want);
        assert!(d < 2e-3, "row {r}: server vs artifact diff {d}");
        assert!(resp.latency_ms >= 0.0);
    }
    let metrics = server.shutdown().unwrap();
    assert_eq!(metrics.requests, cfg.batch);
    assert!(metrics.batches >= 1 && metrics.batches <= cfg.batch);
}

#[test]
fn lowrank_artifact_matches_dense_reconstruction() {
    // the Pallas lowrank kernel path (padded factors) == dense execution
    let Some(engine) = engine_or_skip("lowrank_artifact_matches_dense_reconstruction") else {
        return;
    };
    let (cfg, w, toks) = tiny_setup();
    if !engine.has(cfg.name, "lowrank_nll") {
        return;
    }
    let stats = CalibStats::synthetic(&cfg, 6);
    let opts = CompressOpts { method: Method::SvdLlm, ratio: 0.3, ..Default::default() };
    let (model, _) = methods::compress(&w, &stats, &opts).unwrap();

    // padded factored execution via the AOT artifact (pallas kernel inside)
    let spec = engine.spec(cfg.name, "lowrank_nll").unwrap().clone();
    let lp = drank::lora::padded_params_for_tests(&model).unwrap();
    let mut inputs: Vec<xla::Literal> = lp
        .iter()
        .map(|t| drank::runtime::lit_f32(&t.data, &t.shape).unwrap())
        .collect();
    assert_eq!(inputs.len() + 1, spec.inputs.len());
    inputs.push(lit_i32(&toks, &[cfg.batch, cfg.seq]).unwrap());
    let outs = engine.exec(cfg.name, "lowrank_nll", &inputs).unwrap();
    let got = outs[0].to_vec::<f32>().unwrap();

    let dense = model.to_dense();
    let mut din = engine.weight_literals(&dense).unwrap();
    din.push(lit_i32(&toks, &[cfg.batch, cfg.seq]).unwrap());
    let want = engine.exec(cfg.name, "dense_nll", &din).unwrap()[0]
        .to_vec::<f32>()
        .unwrap();
    let d = max_abs_diff(&got, &want);
    assert!(d < 5e-3, "lowrank artifact vs dense: {d}");
}

#[test]
fn sequential_compensation_pipeline_runs() {
    // §4.1 path: blocks compressed front-to-back with recalibration against
    // the compressed prefix; must hit the target ratio and stay finite
    let Some(engine) = engine_or_skip("sequential_compensation_pipeline_runs") else {
        return;
    };
    let (cfg, w, _) = tiny_setup();
    let data = DataBundle::build_cached(cfg.vocab, 1234, 1.0);
    let copts = CalibOpts { batches: 2, ..Default::default() };
    // n=1 so the tiny 2-layer model has two compensation blocks (with n=2
    // the whole model is one block and compensation degenerates to a no-op)
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.4,
        group_layers: 1,
        compensate: true,
        ..Default::default()
    };
    let (model, plan) = drank::compress::pipeline::compress_model(
        &engine, &w, &data, &copts, &opts,
    )
    .unwrap();
    assert!((model.achieved_ratio() - 0.4).abs() < 0.06, "{}", model.achieved_ratio());
    assert_eq!(plan.len(), 7);
    // still evaluable
    let stream = &data.domain(drank::data::synlang::Domain::Wiki2s).test;
    let ppl = drank::eval::ppl_compressed(&engine, &model, stream, 4).unwrap();
    assert!(ppl.is_finite() && ppl > 1.0, "{ppl}");
    // compensated result differs from uncompensated (recalibration happened)
    let opts2 = CompressOpts { compensate: false, ..opts };
    let (model2, _) = drank::compress::pipeline::compress_model(
        &engine, &w, &data, &copts, &opts2,
    )
    .unwrap();
    let a = model.to_dense();
    let b2 = model2.to_dense();
    let d = max_abs_diff(
        &a.by_name("wq").layer_mat(cfg.layers - 1).data,
        &b2.by_name("wq").layer_mat(cfg.layers - 1).data,
    );
    assert!(d > 0.0, "compensation had no effect on the last layer");
}

#[test]
fn zero_shot_scoring_end_to_end_tiny() {
    // full task pipeline on a briefly-trained tiny model: accuracy must be
    // a valid probability and the easy suite must beat chance
    let Some(engine) = engine_or_skip("zero_shot_scoring_end_to_end_tiny") else {
        return;
    };
    let data = DataBundle::build_cached(256, 1234, 1.0);
    let opts = drank::runtime::trainer::TrainOpts { steps: 60, ..Default::default() };
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let log =
        drank::runtime::trainer::train(&engine, Weights::init(cfg, 3), &data, &opts).unwrap();
    let (accs, avg) = drank::eval::tasks::run_all_suites(
        &engine,
        &log.final_weights,
        &data.tokenizer,
        &data.lexicon,
        30,
        11,
    )
    .unwrap();
    assert_eq!(accs.len(), 7);
    for (suite, acc) in &accs {
        assert!((0.0..=1.0).contains(acc), "{suite:?} {acc}");
    }
    assert!(avg > 0.0 && avg < 1.0);
}

#[test]
fn train_step_reduces_loss_tiny() {
    let Some(engine) = engine_or_skip("train_step_reduces_loss_tiny") else {
        return;
    };
    let (cfg, w, _) = tiny_setup();
    let data = DataBundle::build(cfg.vocab, 4, 0.02);
    let opts = drank::runtime::trainer::TrainOpts {
        steps: 12,
        base_lr: 3e-3,
        warmup: 2,
        log_every: 1,
        seed: 1,
    };
    let log = drank::runtime::trainer::train(&engine, w, &data, &opts).unwrap();
    let first = log.losses.first().unwrap().1;
    let last = log.losses.last().unwrap().1;
    assert!(
        last < first - 0.2,
        "training did not reduce loss: {first} -> {last}"
    );
    assert!(log.tokens_per_sec > 0.0);
}
