//! Compression-pipeline tests over the pure-Rust reference calibration —
//! the compensated (§4.1) path included — with **no** `artifacts/`
//! directory and no PJRT (the recalibration seam in `compress::pipeline`).

use drank::calib::{self, CalibOpts};
use drank::compress::{methods, pipeline, CompressOpts, Method};
use drank::data::DataBundle;
use drank::model::lowrank::TypeRep;
use drank::model::{ModelConfig, Weights, COMPRESSIBLE};

fn setup() -> (ModelConfig, Weights, DataBundle) {
    let cfg = ModelConfig::by_name("tiny").unwrap();
    (cfg, Weights::init(cfg, 42), DataBundle::build(cfg.vocab, 3, 0.02))
}

#[test]
fn reference_calibration_stats_are_sane() {
    let (cfg, w, data) = setup();
    let copts = CalibOpts { batches: 2, ..Default::default() };
    let stats = calib::run_reference(&w, &data, &copts).unwrap();
    assert_eq!(stats.tokens, 2 * cfg.batch * cfg.seq);
    let g = stats.gram("wq", 0);
    assert_eq!(g.rows, cfg.d);
    for i in 0..cfg.d {
        assert!(g.at(i, i) >= 0.0);
        for j in 0..cfg.d {
            assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-9, "asymmetric at ({i},{j})");
        }
    }
    let diag_mean: f64 = (0..cfg.d).map(|i| g.at(i, i)).sum::<f64>() / cfg.d as f64;
    assert!(diag_mean > 0.0);
    // the w_down slot carries dff-dimensional inputs
    assert_eq!(stats.gram("w_down", cfg.layers - 1).rows, cfg.dff);
    assert!(stats.absmean("wq", 0).iter().all(|&v| v >= 0.0));
    // fisher is artifact-only: absent here, and requesting it is an error
    assert!(stats.fisher_rows("wq", 0).is_none());
    let fopts = CalibOpts { batches: 1, fisher: true, ..Default::default() };
    assert!(calib::run_reference(&w, &data, &fopts).is_err());
}

#[test]
fn compensated_reference_pipeline_tiny() {
    let (cfg, w, data) = setup();
    let copts = CalibOpts { batches: 2, ..Default::default() };
    // n=1 so the tiny 2-layer model has two compensation blocks
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.4,
        group_layers: 1,
        compensate: true,
        ..Default::default()
    };
    let (model, plan) = pipeline::compress_model_reference(&w, &data, &copts, &opts).unwrap();
    assert_eq!(plan.len(), 7);
    assert!(
        (model.achieved_ratio() - 0.4).abs() < 0.06,
        "achieved {}",
        model.achieved_ratio()
    );
    // every factored group: finite factors + the factoring guard holds
    let mut factored_groups = 0;
    for typ in COMPRESSIBLE {
        let (d1, d2) = cfg.matrix_dims(typ);
        if let TypeRep::Factored(groups) = &model.reps[typ] {
            for g in groups {
                factored_groups += 1;
                let (k, glen) = (g.rank(), g.n_layers());
                assert!(
                    k * (d1 + glen * d2) < glen * d1 * d2,
                    "{typ}: rank {k} over group of {glen} is not worth factoring"
                );
                assert!(g.b.data.iter().all(|x| x.is_finite()), "{typ}: non-finite basis");
                for c in &g.cs {
                    assert!(c.data.iter().all(|x| x.is_finite()), "{typ}: non-finite coeffs");
                }
            }
        }
    }
    assert!(factored_groups > 0, "nothing was factored at 40%");
    // compensation recalibrated: late layers differ from the uncompensated run
    let opts2 = CompressOpts { compensate: false, ..opts.clone() };
    let (model2, _) = pipeline::compress_model_reference(&w, &data, &copts, &opts2).unwrap();
    let a = model.to_dense();
    let b = model2.to_dense();
    let la = a.by_name("wq").layer_mat(cfg.layers - 1);
    let lb = b.by_name("wq").layer_mat(cfg.layers - 1);
    let d: f32 = la
        .data
        .iter()
        .zip(&lb.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max);
    assert!(d > 0.0, "compensation had no effect on the last layer");
}

#[test]
fn uncompensated_pipeline_matches_direct_compress() {
    // compensate=false must be exactly the plain calibrate-then-compress
    // path (the seam adds no behavior change)
    let (_cfg, w, data) = setup();
    let copts = CalibOpts { batches: 2, ..Default::default() };
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.3,
        group_layers: 2,
        compensate: false,
        ..Default::default()
    };
    let (m1, p1) = pipeline::compress_model_reference(&w, &data, &copts, &opts).unwrap();
    let stats = calib::run_reference(&w, &data, &copts).unwrap();
    let (m2, p2) = methods::compress(&w, &stats, &opts).unwrap();
    assert_eq!(p1, p2, "rank plans diverged");
    let (a, b) = (m1.to_dense(), m2.to_dense());
    for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
        assert_eq!(ta.data, tb.data, "dense reconstructions diverged");
    }
}

#[test]
fn compensated_ratio_accounts_for_skipped_groups() {
    // Force a mid-stack break-even skip: tiny has d=64, so a wq group of one
    // layer breaks even at k = 64*64/(64+64) = 32 — exactly the kmax clamp.
    // At ratio 0.01 the allocator floors both wq groups near 31.7 and greedy
    // repair pushes one to kmax=32, whose factoring (32*128 = 4096 = d1*d2)
    // is skipped, leaving that layer dense. achieved_ratio() must charge it
    // as dense instead of letting it vanish from the count.
    let (cfg, w, data) = setup();
    let copts = CalibOpts { batches: 2, ..Default::default() };
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.01,
        group_layers: 1,
        beta: 0.0, // keep the Q/K/V budgets untouched so the math above holds
        compensate: true,
        ..Default::default()
    };
    let (model, plan) = pipeline::compress_model_reference(&w, &data, &copts, &opts).unwrap();
    assert!(plan.values().any(|ks| ks.contains(&32)), "no group clamped to kmax: {plan:?}");

    // at least one factored type must have an uncovered (skipped) layer
    let mut has_hole = false;
    for typ in COMPRESSIBLE {
        if let TypeRep::Factored(groups) = &model.reps[typ] {
            let covered: usize = groups.iter().map(|g| g.n_layers()).sum();
            assert!(covered <= cfg.layers, "{typ}: overlapping groups");
            if covered < cfg.layers {
                has_hole = true;
            }
        }
    }
    assert!(has_hole, "expected a mid-stack break-even skip at ratio 0.01");

    // hand-computed parameter count: walk layer by layer through the
    // factor lookup, charging dense layers at d1*d2 and each shared basis
    // exactly once (identified by its data pointer)
    let mut expect = 0usize;
    for typ in COMPRESSIBLE {
        let (d1, d2) = cfg.matrix_dims(typ);
        let mut seen_bases: Vec<*const f32> = Vec::new();
        for l in 0..cfg.layers {
            match model.layer_factors(typ, l) {
                None => expect += d1 * d2,
                Some((b, c)) => {
                    expect += c.rows * c.cols;
                    let p = b.data.as_ptr();
                    if !seen_bases.contains(&p) {
                        seen_bases.push(p);
                        expect += b.rows * b.cols;
                    }
                }
            }
        }
    }
    assert_eq!(model.compressible_param_count(), expect);

    // a near-zero target must report a near-zero achieved ratio — the old
    // accounting dropped every skipped layer and reported ~20x the truth
    let got = model.achieved_ratio();
    assert!(got >= 0.0 && got < 0.05, "achieved_ratio {got} should be ~0.01");
}

#[test]
fn compensated_seam_accepts_custom_recalibration() {
    // the recalibration provider is pluggable: count invocations and feed
    // synthetic stats — the §4.1 loop must call it once per block after
    // the first (tiny with n=1 has 2 blocks -> exactly 1 recalibration)
    let (cfg, w, data) = setup();
    let copts = CalibOpts { batches: 2, ..Default::default() };
    let stats0 = calib::run_reference(&w, &data, &copts).unwrap();
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.4,
        group_layers: 1,
        compensate: true,
        ..Default::default()
    };
    let mut calls = 0usize;
    let (model, _) = pipeline::compensated_with(&w, stats0, &opts, |m| {
        calls += 1;
        // the prefix handed back must be a real partially-compressed model,
        // with at least one type already factored (no dense handoff)
        assert_eq!(m.config().name, cfg.name);
        assert!(
            m.reps.values().any(|r| matches!(r, drank::model::lowrank::TypeRep::Factored(_))),
            "recalibration prefix should carry factored types"
        );
        calib::run_reference_model(m, &data, &copts)
    })
    .unwrap();
    // n=1 => one block per layer => layers-1 recalibrations
    assert_eq!(calls, cfg.layers - 1, "one recalibration per later block");
    assert!(model.achieved_ratio() > 0.3);
}
