//! Property suite for the Jacobi eigensolvers: synthesize matrices with
//! *known* spectra (A = V·diag(w)·Vᵀ from a seeded random orthogonal V) and
//! check that both the serial reference (`jacobi_eigen`) and the blocked
//! round-robin solver (`jacobi_eigen_blocked`) recover the planted
//! eigenvalues to 1e-9 relative tolerance — plus the edge cases that never
//! show up in random testing: n ∈ {0, 1, 2}, duplicate eigenvalues,
//! rank-deficient spectra, and near-diagonal inputs.
//!
//! This is the harness that makes eigensolver rewrites safe: any future
//! scheduling change has to reproduce these spectra through both paths.

use drank::linalg::eigen::{jacobi_eigen, jacobi_eigen_blocked, Eigen};
use drank::tensor::MatF;
use drank::util::rng::Rng;

type Solver = fn(&MatF) -> Eigen;

const SOLVERS: [(&str, Solver); 2] =
    [("serial", jacobi_eigen as Solver), ("blocked", jacobi_eigen_blocked as Solver)];

/// Random orthogonal n×n matrix: a product of ~4n seeded Givens rotations
/// applied to the identity. Exactly orthogonal up to f64 rounding, and a
/// pure function of the seed.
fn random_orthogonal(rng: &mut Rng, n: usize) -> MatF {
    let mut v = MatF::identity(n);
    if n < 2 {
        return v;
    }
    for _ in 0..4 * n {
        let p = rng.below(n);
        let mut q = rng.below(n - 1);
        if q >= p {
            q += 1;
        }
        let theta = (rng.uniform() - 0.5) * 2.0 * std::f64::consts::PI;
        let (c, s) = (theta.cos(), theta.sin());
        for k in 0..n {
            let vkp = v.at(k, p);
            let vkq = v.at(k, q);
            *v.at_mut(k, p) = c * vkp - s * vkq;
            *v.at_mut(k, q) = s * vkp + c * vkq;
        }
    }
    v
}

/// A = V·diag(w)·Vᵀ, built exactly symmetric: compute the upper triangle
/// and mirror it (summation order can otherwise differ between (i,j) and
/// (j,i) at the last ulp).
fn spectral_matrix(v: &MatF, w: &[f64]) -> MatF {
    let n = v.rows;
    assert_eq!(w.len(), n);
    let mut a = MatF::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let mut s = 0.0;
            for (k, &wk) in w.iter().enumerate() {
                s += v.at(i, k) * wk * v.at(j, k);
            }
            *a.at_mut(i, j) = s;
            *a.at_mut(j, i) = s;
        }
    }
    a
}

/// Check one planted spectrum through one solver: eigenvalues match the
/// sorted plant within `rel_tol` (relative to the largest magnitude), the
/// eigenvectors are orthonormal, and A·V = V·diag(w).
fn check_recovery(name: &str, solve: Solver, a: &MatF, planted: &[f64], rel_tol: f64) {
    let n = a.rows;
    let e = solve(a);
    assert_eq!(e.values.len(), n, "{name}: wrong spectrum length");
    assert_eq!((e.vectors.rows, e.vectors.cols), (n, n), "{name}: wrong V shape");

    let mut want = planted.to_vec();
    want.sort_by(|x, y| y.partial_cmp(x).unwrap());
    let scale = want.iter().fold(1e-30f64, |m, x| m.max(x.abs()));
    for (i, (got, w)) in e.values.iter().zip(&want).enumerate() {
        assert!(
            (got - w).abs() <= rel_tol * scale,
            "{name}: eigenvalue {i} of n={n}: got {got}, planted {w}"
        );
    }

    let vtv = e.vectors.t_matmul(&e.vectors);
    for i in 0..n {
        for j in 0..n {
            let id = if i == j { 1.0 } else { 0.0 };
            assert!(
                (vtv.at(i, j) - id).abs() < 1e-9,
                "{name}: VᵀV[{i},{j}] = {} for n={n}",
                vtv.at(i, j)
            );
        }
    }

    let av = a.matmul(&e.vectors);
    for j in 0..n {
        for i in 0..n {
            let want_ij = e.vectors.at(i, j) * e.values[j];
            assert!(
                (av.at(i, j) - want_ij).abs() <= 1e-8 * scale.max(1.0),
                "{name}: (A·V)[{i},{j}] mismatch for n={n}"
            );
        }
    }
}

#[test]
fn recovers_planted_random_spectra() {
    for (name, solve) in SOLVERS {
        let mut rng = Rng::new(11);
        for n in [3usize, 8, 17, 48, 96] {
            let v = random_orthogonal(&mut rng, n);
            // well-separated magnitudes across ~4 decades, mixed signs
            let w: Vec<f64> = (0..n)
                .map(|i| {
                    let mag = 10f64.powf(4.0 * (i as f64 / n as f64) - 2.0);
                    if rng.uniform() < 0.3 {
                        -mag
                    } else {
                        mag
                    }
                })
                .collect();
            let a = spectral_matrix(&v, &w);
            check_recovery(name, solve, &a, &w, 1e-9);
        }
    }
}

#[test]
fn recovers_duplicate_eigenvalues() {
    // repeated eigenvalues make individual eigenvectors non-unique, but the
    // spectrum itself — and the invariant-subspace relations checked by
    // check_recovery — must still come out right
    for (name, solve) in SOLVERS {
        let mut rng = Rng::new(12);
        let n = 12;
        let v = random_orthogonal(&mut rng, n);
        let mut w = vec![5.0; 4];
        w.extend(vec![-2.0; 4]);
        w.extend(vec![0.25; 4]);
        let a = spectral_matrix(&v, &w);
        check_recovery(name, solve, &a, &w, 1e-9);
    }
}

#[test]
fn recovers_rank_deficient_spectra() {
    // exact zeros in the plant: the compression path hits this on every
    // rank-deficient calibration Gram
    for (name, solve) in SOLVERS {
        let mut rng = Rng::new(13);
        let n = 15;
        let v = random_orthogonal(&mut rng, n);
        let mut w: Vec<f64> = (0..5).map(|i| 3.0 / (1 << i) as f64).collect();
        w.extend(vec![0.0; n - 5]);
        let a = spectral_matrix(&v, &w);
        check_recovery(name, solve, &a, &w, 1e-9);
        let e = solve(&a);
        for &val in &e.values[5..] {
            assert!(val.abs() < 1e-9 * 3.0, "{name}: zero eigenvalue drifted to {val}");
        }
    }
}

#[test]
fn near_diagonal_inputs_converge_fast_and_exact() {
    // tiny off-diagonal coupling: one threshold sweep must polish this off
    // without disturbing the dominant diagonal
    for (name, solve) in SOLVERS {
        let n = 20;
        let mut a = MatF::zeros(n, n);
        for i in 0..n {
            *a.at_mut(i, i) = (n - i) as f64;
        }
        for i in 0..n - 1 {
            *a.at_mut(i, i + 1) = 1e-10;
            *a.at_mut(i + 1, i) = 1e-10;
        }
        let planted: Vec<f64> = (0..n).map(|i| (n - i) as f64).collect();
        check_recovery(name, solve, &a, &planted, 1e-9);
    }
}

#[test]
fn exactly_diagonal_input_is_reproduced() {
    for (name, solve) in SOLVERS {
        let w = [9.0, -3.5, 0.0, 2.25, -7.0];
        let n = w.len();
        let mut a = MatF::zeros(n, n);
        for (i, &x) in w.iter().enumerate() {
            *a.at_mut(i, i) = x;
        }
        let e = solve(&a);
        assert_eq!(e.values, vec![9.0, 2.25, 0.0, -3.5, -7.0], "{name}");
    }
}

#[test]
fn edge_case_n0_n1_n2() {
    for (name, solve) in SOLVERS {
        // n = 0: empty but well-formed
        let e = solve(&MatF::zeros(0, 0));
        assert!(e.values.is_empty(), "{name}");
        assert_eq!((e.vectors.rows, e.vectors.cols), (0, 0), "{name}");

        // n = 1: passthrough
        let e = solve(&MatF::from_vec(1, 1, vec![4.75]));
        assert_eq!(e.values, vec![4.75], "{name}");
        assert_eq!(e.vectors.data, vec![1.0], "{name}");

        // n = 2: closed-form check against the quadratic formula
        let (p, q, r) = (3.0, 1.5, -1.0);
        let a = MatF::from_vec(2, 2, vec![p, q, q, r]);
        let disc = ((p - r) * (p - r) / 4.0 + q * q).sqrt();
        let planted = [(p + r) / 2.0 + disc, (p + r) / 2.0 - disc];
        check_recovery(name, solve, &a, &planted, 1e-12);
    }
}

#[test]
fn serial_and_blocked_spectra_agree_on_random_inputs() {
    // not bit-identity (the two schedules round differently) but tight
    // agreement — bit-identity across *thread counts* of the blocked path
    // is enforced in rust/tests/determinism.rs
    let mut rng = Rng::new(14);
    for n in [6usize, 23, 64] {
        let v = random_orthogonal(&mut rng, n);
        let w: Vec<f64> = (0..n).map(|i| (i as f64) - n as f64 / 3.0).collect();
        let a = spectral_matrix(&v, &w);
        let es = jacobi_eigen(&a);
        let eb = jacobi_eigen_blocked(&a);
        let scale = es.values.iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for (s, b) in es.values.iter().zip(&eb.values) {
            assert!((s - b).abs() <= 1e-9 * scale, "n={n}: serial {s} vs blocked {b}");
        }
    }
}
