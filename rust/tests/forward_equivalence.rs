//! Equivalence suite for the batched serving forward (`model::fwd`).
//!
//! Three contracts, all artifact-free:
//!  (a) the batched row-band-parallel GEMM forward matches a scalar
//!      per-token oracle (a frozen copy of the historical loop-level
//!      forward) to 1e-5 NLL on the tiny, long-sequence ("s", seq 96) and
//!      GQA configs — the long runs drive the blocked streaming-softmax
//!      attention across many query/key tiles;
//!  (b) factored serving (`fwd::nll_model`) matches `to_dense()` serving
//!      to within factorization tolerance for all six methods — the
//!      (x·B)·C vs x·(B·C) association gap, nothing more;
//!  (c) the new forward is bit-identical (`to_bits`) across 1/2/4 threads,
//!      dense and factored — registered in the determinism CI matrix like
//!      `rust/tests/determinism.rs`.

use std::sync::Mutex;

use drank::calib::CalibStats;
use drank::compress::{methods, CompressOpts, Method};
use drank::model::lowrank::CompressedModel;
use drank::model::{fwd, ModelConfig, Weights};
use drank::util::parallel::set_threads;
use drank::util::rng::Rng;

/// `set_threads` is process-global; serialize tests that touch it.
static THREAD_LOCK: Mutex<()> = Mutex::new(());

fn tiny_setup(seed: u64) -> (ModelConfig, Weights, Vec<i32>) {
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, seed);
    let mut r = Rng::new(seed.wrapping_add(100));
    let toks: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|_| r.below(cfg.vocab) as i32).collect();
    (cfg, w, toks)
}

fn all_methods() -> Vec<Method> {
    vec![
        Method::PlainSvd,
        Method::Fwsvd,
        Method::Asvd,
        Method::SvdLlm,
        Method::BasisSharing,
        Method::DRank,
    ]
}

// ---------------------------------------------------------- scalar oracle
//
// A frozen copy of the historical per-token scalar forward (pre-GEMM
// `model/fwd.rs`), kept here as the numerical reference the batched
// forward must reproduce. Deliberately self-contained: it shares no code
// with the implementation under test.
mod oracle {
    use drank::model::Weights;

    const EPS: f32 = 1e-5;
    const ROPE_THETA: f32 = 1e4;

    pub fn nll(w: &Weights, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
        let cfg = w.config;
        let t = seq - 1;
        let hidden = forward_hidden(w, tokens, batch, seq, t);
        let lm = w.by_name("lm_head");
        let (d, v) = (cfg.d, cfg.vocab);
        let mut out = vec![0.0f32; batch * t];
        let mut logits = vec![0.0f32; v];
        for b in 0..batch {
            for pos in 0..t {
                let h = &hidden[(b * t + pos) * d..(b * t + pos + 1) * d];
                for x in logits.iter_mut() {
                    *x = 0.0;
                }
                for (i, &hv) in h.iter().enumerate() {
                    if hv == 0.0 {
                        continue;
                    }
                    let row = &lm.data[i * v..(i + 1) * v];
                    for j in 0..v {
                        logits[j] += hv * row[j];
                    }
                }
                let max = logits.iter().cloned().fold(f32::MIN, f32::max);
                let logz = max + logits.iter().map(|x| (x - max).exp()).sum::<f32>().ln();
                let target = tokens[b * seq + pos + 1] as usize;
                out[b * t + pos] = logz - logits[target];
            }
        }
        out
    }

    fn forward_hidden(w: &Weights, tokens: &[i32], batch: usize, seq: usize, t: usize) -> Vec<f32> {
        let cfg = w.config;
        let d = cfg.d;
        let embed = w.by_name("embed");
        let mut x = vec![0.0f32; batch * t * d];
        for b in 0..batch {
            for pos in 0..t {
                let tok = tokens[b * seq + pos] as usize;
                x[(b * t + pos) * d..(b * t + pos + 1) * d]
                    .copy_from_slice(&embed.data[tok * d..(tok + 1) * d]);
            }
        }
        let (cos, sin) = rope_tables(t, cfg.head_dim());
        for l in 0..cfg.layers {
            attention_block(w, &mut x, batch, t, l, &cos, &sin);
            mlp_block(w, &mut x, batch, t, l);
        }
        let fnorm = &w.by_name("final_norm").data;
        for row in x.chunks_exact_mut(d) {
            rmsnorm_inplace(row, fnorm);
        }
        x
    }

    fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
        let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for i in 0..x.len() {
            out[i] = x[i] * inv * w[i];
        }
    }

    fn rmsnorm_inplace(x: &mut [f32], w: &[f32]) {
        let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for i in 0..x.len() {
            x[i] *= inv * w[i];
        }
    }

    fn rope_tables(t: usize, hd: usize) -> (Vec<f32>, Vec<f32>) {
        let half = hd / 2;
        let mut cos = vec![0.0f32; t * half];
        let mut sin = vec![0.0f32; t * half];
        for p in 0..t {
            for i in 0..half {
                let freq = ROPE_THETA.powf(-(i as f32) / half as f32);
                let ang = p as f32 * freq;
                cos[p * half + i] = ang.cos();
                sin[p * half + i] = ang.sin();
            }
        }
        (cos, sin)
    }

    fn apply_rope(v: &mut [f32], p: usize, cos: &[f32], sin: &[f32]) {
        let half = v.len() / 2;
        for i in 0..half {
            let c = cos[p * half + i];
            let s = sin[p * half + i];
            let x1 = v[i];
            let x2 = v[half + i];
            v[i] = x1 * c - x2 * s;
            v[half + i] = x2 * c + x1 * s;
        }
    }

    fn matvec_add(x: &[f32], w: &[f32], d_out: usize, y: &mut [f32]) {
        for (i, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &w[i * d_out..(i + 1) * d_out];
            for j in 0..d_out {
                y[j] += xv * row[j];
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn attention_block(
        w: &Weights,
        x: &mut [f32],
        batch: usize,
        t: usize,
        l: usize,
        cos: &[f32],
        sin: &[f32],
    ) {
        let cfg = w.config;
        let (d, h, kvh, hd) = (cfg.d, cfg.heads, cfg.kv_heads, cfg.head_dim());
        let kvd = cfg.kvd();
        let an = &w.by_name("attn_norm").data[l * d..(l + 1) * d];
        let wq = &w.by_name("wq").data[l * d * d..(l + 1) * d * d];
        let wk = &w.by_name("wk").data[l * d * kvd..(l + 1) * d * kvd];
        let wv = &w.by_name("wv").data[l * d * kvd..(l + 1) * d * kvd];
        let wo = &w.by_name("wo").data[l * d * d..(l + 1) * d * d];
        let rep = h / kvh;
        let scale = 1.0 / (hd as f32).sqrt();

        let mut xn = vec![0.0f32; d];
        for b in 0..batch {
            let mut q = vec![0.0f32; t * d];
            let mut k = vec![0.0f32; t * kvd];
            let mut v = vec![0.0f32; t * kvd];
            for pos in 0..t {
                let row = &x[(b * t + pos) * d..(b * t + pos + 1) * d];
                rmsnorm(row, an, &mut xn);
                matvec_add(&xn, wq, d, &mut q[pos * d..(pos + 1) * d]);
                matvec_add(&xn, wk, kvd, &mut k[pos * kvd..(pos + 1) * kvd]);
                matvec_add(&xn, wv, kvd, &mut v[pos * kvd..(pos + 1) * kvd]);
                for head in 0..h {
                    apply_rope(&mut q[pos * d + head * hd..pos * d + (head + 1) * hd], pos, cos, sin);
                }
                for head in 0..kvh {
                    apply_rope(
                        &mut k[pos * kvd + head * hd..pos * kvd + (head + 1) * hd],
                        pos,
                        cos,
                        sin,
                    );
                }
            }
            let mut attn = vec![0.0f32; t * d];
            let mut scores = vec![0.0f32; t];
            for head in 0..h {
                let kv_head = head / rep;
                for pos in 0..t {
                    let qv = &q[pos * d + head * hd..pos * d + (head + 1) * hd];
                    let mut max = f32::MIN;
                    for j in 0..=pos {
                        let kv = &k[j * kvd + kv_head * hd..j * kvd + (kv_head + 1) * hd];
                        let s: f32 = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                        scores[j] = s;
                        max = max.max(s);
                    }
                    let mut denom = 0.0f32;
                    for s in scores[..=pos].iter_mut() {
                        *s = (*s - max).exp();
                        denom += *s;
                    }
                    let out = &mut attn[pos * d + head * hd..pos * d + (head + 1) * hd];
                    for j in 0..=pos {
                        let p = scores[j] / denom;
                        let vv = &v[j * kvd + kv_head * hd..j * kvd + (kv_head + 1) * hd];
                        for i in 0..hd {
                            out[i] += p * vv[i];
                        }
                    }
                }
            }
            for pos in 0..t {
                let row = &mut x[(b * t + pos) * d..(b * t + pos + 1) * d];
                let mut o = vec![0.0f32; d];
                matvec_add(&attn[pos * d..(pos + 1) * d], wo, d, &mut o);
                for i in 0..d {
                    row[i] += o[i];
                }
            }
        }
    }

    fn mlp_block(w: &Weights, x: &mut [f32], batch: usize, t: usize, l: usize) {
        let cfg = w.config;
        let (d, dff) = (cfg.d, cfg.dff);
        let mn = &w.by_name("mlp_norm").data[l * d..(l + 1) * d];
        let wg = &w.by_name("w_gate").data[l * d * dff..(l + 1) * d * dff];
        let wu = &w.by_name("w_up").data[l * d * dff..(l + 1) * d * dff];
        let wd = &w.by_name("w_down").data[l * dff * d..(l + 1) * dff * d];
        let mut xn = vec![0.0f32; d];
        let mut g = vec![0.0f32; dff];
        let mut u = vec![0.0f32; dff];
        for bt in 0..batch * t {
            let row = &mut x[bt * d..(bt + 1) * d];
            rmsnorm(row, mn, &mut xn);
            g.iter_mut().for_each(|x| *x = 0.0);
            u.iter_mut().for_each(|x| *x = 0.0);
            matvec_add(&xn, wg, dff, &mut g);
            matvec_add(&xn, wu, dff, &mut u);
            for i in 0..dff {
                let s = g[i] / (1.0 + (-g[i]).exp());
                g[i] = s * u[i];
            }
            let mut o = vec![0.0f32; d];
            matvec_add(&g, wd, d, &mut o);
            for i in 0..d {
                row[i] += o[i];
            }
        }
    }
}

// --------------------------------------------------------------- (a) GEMM

#[test]
fn batched_forward_matches_scalar_oracle() {
    let (cfg, w, toks) = tiny_setup(3);
    let got = fwd::nll(&w, &toks, cfg.batch, cfg.seq);
    let want = oracle::nll(&w, &toks, cfg.batch, cfg.seq);
    assert_eq!(got.len(), want.len());
    for (i, (g, o)) in got.iter().zip(&want).enumerate() {
        assert!((g - o).abs() < 1e-5, "position {i}: batched {g} vs scalar {o}");
    }
}

#[test]
fn batched_forward_matches_scalar_oracle_on_gqa() {
    // grouped-query attention exercises the kv_head = head/rep indexing
    let cfg = ModelConfig::by_name("gqa").unwrap();
    let w = Weights::init(cfg, 21);
    let mut r = Rng::new(22);
    let (b, s) = (2usize, 24usize);
    let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
    let got = fwd::nll(&w, &toks, b, s);
    let want = oracle::nll(&w, &toks, b, s);
    for (i, (g, o)) in got.iter().zip(&want).enumerate() {
        assert!((g - o).abs() < 1e-5, "position {i}: batched {g} vs scalar {o}");
    }
}

#[test]
fn batched_forward_matches_scalar_oracle_on_long_sequences() {
    // seq 96 spans several ATTN_TQ=16 query tiles and ATTN_TK=32 key
    // tiles, so the streaming-softmax rescale path (running max rising
    // mid-row across tile boundaries) is exercised — not just the
    // single-tile case the tiny config covers
    let cfg = ModelConfig::by_name("s").unwrap();
    let w = Weights::init(cfg, 31);
    let mut r = Rng::new(32);
    let (b, s) = (2usize, 96usize);
    let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
    let got = fwd::nll(&w, &toks, b, s);
    let want = oracle::nll(&w, &toks, b, s);
    assert_eq!(got.len(), want.len());
    for (i, (g, o)) in got.iter().zip(&want).enumerate() {
        assert!((g - o).abs() < 1e-5, "position {i}: batched {g} vs scalar {o}");
    }
}

#[test]
fn batched_forward_matches_scalar_oracle_on_gqa_long_sequences() {
    // GQA head sharing (kv_head = head / rep) combined with a sequence
    // long enough that every query tile walks multiple k/v tiles
    let cfg = ModelConfig::by_name("gqa").unwrap();
    let w = Weights::init(cfg, 33);
    let mut r = Rng::new(34);
    let (b, s) = (1usize, 96usize);
    let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
    let got = fwd::nll(&w, &toks, b, s);
    let want = oracle::nll(&w, &toks, b, s);
    for (i, (g, o)) in got.iter().zip(&want).enumerate() {
        assert!((g - o).abs() < 1e-5, "position {i}: batched {g} vs scalar {o}");
    }
}

#[test]
fn attention_shape_edges_match_scalar_oracle() {
    // the streaming-softmax kernel tiles queries by ATTN_TQ=16 and keys by
    // ATTN_TK=32; sweep t = seq-1 over the shape edges: t = 1 (single
    // query row), t < 32 (single partial key tile), and t not a multiple
    // of either tile (ragged final query *and* key tiles)
    let cfg = ModelConfig::by_name("tiny").unwrap();
    let w = Weights::init(cfg, 41);
    let mut r = Rng::new(42);
    for seq in [2usize, 3, 17, 18, 33, 34, 49, 51] {
        let b = 2usize;
        let toks: Vec<i32> = (0..b * seq).map(|_| r.below(cfg.vocab) as i32).collect();
        let got = fwd::nll(&w, &toks, b, seq);
        let want = oracle::nll(&w, &toks, b, seq);
        assert_eq!(got.len(), want.len(), "seq {seq}");
        for (i, (g, o)) in got.iter().zip(&want).enumerate() {
            assert!((g - o).abs() < 1e-5, "seq {seq} position {i}: {g} vs {o}");
        }
    }
}

// ----------------------------------------------------------- (b) factored

#[test]
fn factored_serving_matches_dense_reconstruction_all_methods() {
    let (cfg, w, toks) = tiny_setup(7);
    let stats = CalibStats::synthetic(&cfg, 11);
    for method in all_methods() {
        let opts = CompressOpts {
            method,
            ratio: 0.3,
            group_layers: 2,
            ..Default::default()
        };
        let (model, _) = methods::compress(&w, &stats, &opts).unwrap();
        assert!(
            model.achieved_ratio() > 0.0,
            "{method:?} produced no compression — test would be vacuous"
        );
        let factored = fwd::nll_model(&model, &toks, cfg.batch, cfg.seq);
        let dense = fwd::nll(&model.to_dense(), &toks, cfg.batch, cfg.seq);
        assert_eq!(factored.len(), dense.len());
        for (i, (f, d)) in factored.iter().zip(&dense).enumerate() {
            // only the (x·B)·C vs x·(B·C) f32 association gap separates the
            // two paths; 2e-2 absolute on ~ln(256) NLLs is generous
            assert!((f - d).abs() < 2e-2, "{method:?} position {i}: {f} vs {d}");
        }
    }
}

// -------------------------------------------------------- (c) determinism

#[test]
fn forward_bit_identical_across_thread_counts() {
    let _guard = THREAD_LOCK.lock().unwrap();
    let (cfg, w, toks) = tiny_setup(13);
    let stats = CalibStats::synthetic(&cfg, 17);
    let opts = CompressOpts {
        method: Method::DRank,
        ratio: 0.3,
        group_layers: 2,
        ..Default::default()
    };
    let (model, _) = methods::compress(&w, &stats, &opts).unwrap();
    let fingerprint = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();

    set_threads(1);
    let dense1 = fingerprint(&fwd::nll(&w, &toks, cfg.batch, cfg.seq));
    let fact1 = fingerprint(&fwd::nll_model(&model, &toks, cfg.batch, cfg.seq));
    for t in [2usize, 4] {
        set_threads(t);
        let dense_t = fingerprint(&fwd::nll(&w, &toks, cfg.batch, cfg.seq));
        let fact_t = fingerprint(&fwd::nll_model(&model, &toks, cfg.batch, cfg.seq));
        assert_eq!(dense1, dense_t, "dense forward differs at {t} threads");
        assert_eq!(fact1, fact_t, "factored forward differs at {t} threads");
    }
    set_threads(0);
}

#[test]
fn calibration_observer_bit_identical_across_thread_counts() {
    // the instrumented forward (batched projections + in-order row
    // recording) must produce bit-identical calibration sums at any pool
    // size — dense and factored
    let _guard = THREAD_LOCK.lock().unwrap();
    let (cfg, w, toks) = tiny_setup(19);
    let m = CompressedModel::dense_passthrough(w.clone());
    let run = |threads: usize| {
        set_threads(threads);
        let mut sd = fwd::CalibSums::new(&cfg);
        fwd::accumulate_calib(&w, &toks, cfg.batch, cfg.seq, &mut sd);
        let mut sm = fwd::CalibSums::new(&cfg);
        fwd::accumulate_calib_model(&m, &toks, cfg.batch, cfg.seq, &mut sm);
        (sd, sm)
    };
    let (d1, m1) = run(1);
    for t in [2usize, 4] {
        let (dt, mt) = run(t);
        for slot in 0..4 {
            for l in 0..cfg.layers {
                let bits = |g: &drank::tensor::MatF| {
                    g.data.iter().map(|x| x.to_bits()).collect::<Vec<u64>>()
                };
                assert_eq!(
                    bits(&d1.grams[slot][l]),
                    bits(&dt.grams[slot][l]),
                    "dense gram slot {slot} layer {l} differs at {t} threads"
                );
                assert_eq!(
                    bits(&m1.grams[slot][l]),
                    bits(&mt.grams[slot][l]),
                    "model gram slot {slot} layer {l} differs at {t} threads"
                );
            }
        }
    }
    set_threads(0);
}
