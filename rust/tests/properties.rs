//! Randomized property tests (proptest is unavailable offline; this is a
//! seed-sweep harness over the same invariants a proptest suite would
//! check — every case prints its seed on failure for reproduction).

use drank::compress::alloc::{beta_rebalance, lagrange_alloc, uniform_rank, GroupSpec};
use drank::compress::layer_groups;
use drank::linalg::svd::svd;
use drank::linalg::{cholesky_jitter, effective_rank, solve_lower, solve_lower_t};
use drank::tensor::MatF;
use drank::tokenizer::Tokenizer;
use drank::util::json::Json;
use drank::util::rng::Rng;

const CASES: u64 = 40;

fn randm(rng: &mut Rng, r: usize, c: usize) -> MatF {
    MatF::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
}

#[test]
fn prop_lagrange_alloc_invariants() {
    for seed in 0..CASES {
        let mut r = Rng::new(seed);
        let g = 1 + r.below(12);
        let specs: Vec<GroupSpec> = (0..g)
            .map(|_| GroupSpec {
                reff: 0.5 + r.uniform() * 1000.0,
                omega: 16 + r.below(512),
                kmax: 4 + r.below(256),
            })
            .collect();
        let max_spend: usize = specs.iter().map(|s| s.kmax * s.omega).sum();
        let budget = (0.1 + 0.8 * r.uniform()) * max_spend as f64;
        let ks = lagrange_alloc(&specs, budget);
        assert_eq!(ks.len(), g, "seed {seed}");
        let spent: usize = ks.iter().zip(&specs).map(|(&k, s)| k * s.omega).sum();
        for (k, s) in ks.iter().zip(&specs) {
            assert!(*k >= 1 && *k <= s.kmax, "seed {seed}: k {k} kmax {}", s.kmax);
        }
        // budget respected unless the 1-rank floor alone exceeds it
        let floor: usize = specs.iter().map(|s| s.omega).sum();
        if floor as f64 <= budget {
            assert!(spent as f64 <= budget + 1e-6, "seed {seed}: {spent} > {budget}");
        }
        // permutation equivariance
        let mut perm: Vec<usize> = (0..g).collect();
        r.shuffle(&mut perm);
        let specs_p: Vec<GroupSpec> = perm.iter().map(|&i| specs[i].clone()).collect();
        let ks_p = lagrange_alloc(&specs_p, budget);
        for (pi, &i) in perm.iter().enumerate() {
            assert_eq!(ks_p[pi], ks[i], "seed {seed}: not permutation-equivariant");
        }
    }
}

#[test]
fn prop_beta_rebalance_never_inflates_params() {
    for seed in 0..CASES {
        let mut r = Rng::new(1000 + seed);
        let g = 1 + r.below(8);
        let kq: Vec<usize> = (0..g).map(|_| 2 + r.below(200)).collect();
        let kk: Vec<usize> = (0..g).map(|_| 2 + r.below(200)).collect();
        let kv: Vec<usize> = (0..g).map(|_| 2 + r.below(200)).collect();
        let (oq, ok, ov) = (32 + r.below(512), 32 + r.below(512), 32 + r.below(512));
        let kmax = vec![10_000usize; g];
        let beta = r.uniform() * 0.9;
        let (q2, k2, v2) = beta_rebalance(beta, &kq, &kk, &kv, oq, ok, ov, &kmax);
        let before: usize = kq.iter().map(|k| k * oq).sum::<usize>()
            + kk.iter().map(|k| k * ok).sum::<usize>()
            + kv.iter().map(|k| k * ov).sum::<usize>();
        let after: usize = q2.iter().map(|k| k * oq).sum::<usize>()
            + k2.iter().map(|k| k * ok).sum::<usize>()
            + v2.iter().map(|k| k * ov).sum::<usize>();
        assert!(after <= before, "seed {seed}: {after} > {before}");
        // V never loses, Q/K never gain
        assert!(v2.iter().zip(&kv).all(|(a, b)| a >= b), "seed {seed}");
        assert!(q2.iter().zip(&kq).all(|(a, b)| a <= b), "seed {seed}");
        assert!(k2.iter().zip(&kk).all(|(a, b)| a <= b), "seed {seed}");
        // everyone keeps at least rank 1
        assert!(q2.iter().all(|&k| k >= 1), "seed {seed}");
    }
}

#[test]
fn prop_uniform_rank_achieves_ratio() {
    for seed in 0..CASES {
        let mut r = Rng::new(2000 + seed);
        let d1 = 16 + r.below(512);
        let d2 = 16 + r.below(512);
        let n = 1 + r.below(5);
        let ratio = 0.1 + 0.7 * r.uniform();
        let k = uniform_rank(d1, d2, n, ratio);
        let params = k * (d1 + n * d2);
        let dense = n * d1 * d2;
        // achieved ratio >= target (floor), within one rank-unit of target
        assert!(params <= dense, "seed {seed}");
        let achieved = 1.0 - params as f64 / dense as f64;
        assert!(achieved + ((d1 + n * d2) as f64 / dense as f64) >= ratio - 1e-9, "seed {seed}");
    }
}

#[test]
fn prop_effective_rank_bounds() {
    for seed in 0..CASES {
        let mut r = Rng::new(3000 + seed);
        let n = 1 + r.below(100);
        let sigma: Vec<f64> = (0..n).map(|_| r.uniform() * 10.0 + 1e-6).collect();
        let reff = effective_rank(&sigma);
        assert!(reff >= 1.0 - 1e-9, "seed {seed}: {reff}");
        assert!(reff <= n as f64 + 1e-9, "seed {seed}: {reff} > {n}");
        // scale invariance
        let scaled: Vec<f64> = sigma.iter().map(|s| s * 7.3).collect();
        assert!((effective_rank(&scaled) - reff).abs() < 1e-9, "seed {seed}");
    }
}

#[test]
fn prop_svd_reconstruction_and_eckart_young() {
    for seed in 0..10 {
        let mut r = Rng::new(4000 + seed);
        let m = 2 + r.below(40);
        let n = 2 + r.below(40);
        let a = randm(&mut r, m, n);
        let d = svd(&a);
        let full = d.reconstruct(m.min(n));
        assert!(full.sub(&a).frob_norm() / a.frob_norm() < 1e-8, "seed {seed}");
        let k = 1 + r.below(m.min(n));
        let err = d.reconstruct(k).sub(&a).frob_norm();
        let tail: f64 = d.s[k..].iter().map(|s| s * s).sum::<f64>().sqrt();
        assert!((err - tail).abs() < 1e-7, "seed {seed}: {err} vs {tail}");
    }
}

#[test]
fn prop_cholesky_solve_roundtrip() {
    for seed in 0..10 {
        let mut r = Rng::new(5000 + seed);
        let n = 2 + r.below(40);
        let x = randm(&mut r, n + 8, n);
        let mut g = x.t_matmul(&x);
        g.scale(1.0 / (n + 8) as f64);
        let (l, _) = cholesky_jitter(&g);
        let b = randm(&mut r, n, 3);
        let y = solve_lower(&l, &b);
        let rec = l.matmul(&y);
        assert!(rec.sub(&b).frob_norm() < 1e-7, "seed {seed}");
        let z = solve_lower_t(&l, &b);
        let rec2 = l.transpose().matmul(&z);
        assert!(rec2.sub(&b).frob_norm() < 1e-7, "seed {seed}");
    }
}

#[test]
fn prop_layer_groups_partition() {
    for seed in 0..CASES {
        let mut r = Rng::new(6000 + seed);
        let layers = 1 + r.below(32);
        let n = 1 + r.below(8);
        let groups = layer_groups(layers, n);
        let mut covered = vec![false; layers];
        for (start, len) in groups {
            assert!(len >= 1 && len <= n, "seed {seed}");
            for l in start..start + len {
                assert!(!covered[l], "seed {seed}: overlap at {l}");
                covered[l] = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "seed {seed}: gap");
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.uniform() < 0.5),
            2 => Json::Num((r.normal() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let n = r.below(8);
                Json::Str((0..n).map(|_| "ab\"\\\nxyz é".chars().nth(r.below(9)).unwrap()).collect())
            }
            4 => Json::Arr((0..r.below(4)).map(|_| random_json(r, depth - 1)).collect()),
            _ => Json::Obj(
                (0..r.below(4))
                    .map(|i| (format!("k{i}"), random_json(r, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..CASES {
        let mut r = Rng::new(7000 + seed);
        let v = random_json(&mut r, 3);
        let text = v.emit();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

#[test]
fn prop_tokenizer_roundtrips_synlang() {
    let lex = drank::data::synlang::Lexicon::new();
    for seed in 0..6 {
        let mut g = drank::data::synlang::Generator::new(
            &lex,
            drank::data::synlang::Domain::C4s,
            seed,
        );
        let corpus = g.corpus(30_000);
        let tok = Tokenizer::train(&corpus, 200 + (seed as usize) * 50);
        let sample = g.corpus(2_000);
        let ids = tok.encode(&sample);
        assert_eq!(tok.decode(&ids), sample, "seed {seed}");
    }
}
