//! Table 7: PPL across model scales at 20% compression on wiki2s —
//! the 7B/13B/30B axis mapped to s / m / l.

#[path = "common/mod.rs"]
mod common;

use drank::compress::Method;
use drank::data::synlang::Domain;
use drank::report::{fmt_ppl, Table};

fn main() {
    let scales = ["s", "m", "l"];
    let methods = [Method::SvdLlm, Method::BasisSharing, Method::DRank];
    let mut rows: Vec<Vec<String>> =
        methods.iter().map(|m| vec![m.name().to_string()]).collect();
    let mut orig = vec!["Original".to_string()];

    for name in scales {
        let b = common::setup(name);
        let stats = b.calibrate(Domain::Wiki2s, false);
        orig.push(fmt_ppl(b.ppl_dense(&b.weights, Domain::Wiki2s)));
        for (mi, method) in methods.into_iter().enumerate() {
            let model = b.compress(&stats, &common::opts(method, 0.2, 2));
            rows[mi].push(fmt_ppl(b.ppl(&model, Domain::Wiki2s)));
            eprint!(".");
        }
        eprintln!(" {name} done");
    }

    let mut t = Table::new(
        "Table 7: PPL across scales @ 20% (wiki2s)",
        &["Method", "s (7B-analog)", "m (13B-analog)", "l (30B-analog)"],
    );
    t.row(orig);
    for r in rows {
        t.row(r);
    }
    common::emit(&t, "table7_scales");
}
