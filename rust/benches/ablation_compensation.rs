//! Ablation: sequential compensation (paper §4.1 — "we adaptively update
//! the downstream layer weights using the deviated inputs" at ratios ≥40%).
//!
//! Compares compensation off vs on for SVD-LLM and D-Rank at 40% and 50%.
//! Expected shape: compensation helps at high ratios (whitening against
//! the activations the compressed prefix actually produces).

#[path = "common/mod.rs"]
mod common;

use drank::compress::{pipeline, CompressOpts, Method};
use drank::data::synlang::Domain;
use drank::report::{fmt_ppl, Table};

fn main() {
    let b = common::setup("m");
    let ratios = [0.4, 0.5];

    let mut t = Table::new(
        "Ablation: sequential compensation (m, wiki2s)",
        &["Method", "40%", "40%+comp", "50%", "50%+comp"],
    );
    for method in [Method::SvdLlm, Method::DRank] {
        let mut cells = vec![method.name().to_string()];
        for &ratio in &ratios {
            for compensate in [false, true] {
                let opts = CompressOpts {
                    method,
                    ratio,
                    group_layers: 2,
                    compensate,
                    ..Default::default()
                };
                let copts = b.calib_opts(Domain::Wiki2s, false);
                let (model, _) = pipeline::compress_model(
                    &b.engine, &b.weights, &b.data, &copts, &opts,
                )
                .expect("compress");
                cells.push(fmt_ppl(b.ppl(&model, Domain::Wiki2s)));
                eprint!(".");
            }
        }
        t.row(cells);
        eprintln!(" {} done", method.name());
    }
    common::emit(&t, "ablation_compensation");
}
