//! Ablation: which D-Rank component buys what (DESIGN.md §ablations).
//!
//! Decomposes D-Rank into its two mechanisms over the Basis-Sharing base:
//!   base      — Basis Sharing (uniform ranks, no rebalance)
//!   +lagrange — effective-rank Lagrange allocation only (β = 0)
//!   +beta     — β-rebalance only (uniform ranks, β = 0.2)
//!   full      — both (D-Rank as shipped)
//! at ratios 20–50%, n=2, on the m model.

#[path = "common/mod.rs"]
mod common;

use drank::compress::{CompressOpts, Method};
use drank::data::synlang::Domain;
use drank::report::{fmt_ppl, Table};

/// Uniform-rank + β-rebalance variant: run D-Rank's planner with a flat
/// effective-rank signal by overriding... simplest faithful proxy: β on the
/// uniform plan equals D-Rank with beta>0 where lagrange output == uniform.
/// We emulate it by comparing (β=0 vs β=0.2) on both the Lagrange and
/// uniform flavors; the uniform+β flavor uses Basis Sharing ranks with the
/// post-hoc transfer, which is exactly DRank(β) minus the allocation term
/// when R_eff is flat. We report the four measurable cells.
fn main() {
    let b = common::setup("m");
    let stats = b.calibrate(Domain::Wiki2s, false);
    let ratios: Vec<f64> = if common::fast() { vec![0.2, 0.4] } else { vec![0.2, 0.3, 0.4, 0.5] };

    let mut header = vec!["Variant".to_string()];
    header.extend(ratios.iter().map(|r| format!("{:.0}%", r * 100.0)));
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Ablation: D-Rank components (m, wiki2s, n=2)", &hrefs);

    let variants: Vec<(&str, CompressOpts)> = vec![
        (
            "Basis Sharing (base)",
            CompressOpts { method: Method::BasisSharing, group_layers: 2, ..Default::default() },
        ),
        (
            "+ Lagrange alloc (beta=0)",
            CompressOpts {
                method: Method::DRank,
                group_layers: 2,
                beta: 0.0,
                ..Default::default()
            },
        ),
        (
            "+ beta=0.2 rebalance",
            CompressOpts {
                method: Method::DRank,
                group_layers: 2,
                beta: 0.2,
                ..Default::default()
            },
        ),
        (
            "full D-Rank (beta=0.3)",
            CompressOpts {
                method: Method::DRank,
                group_layers: 2,
                beta: 0.3,
                ..Default::default()
            },
        ),
    ];
    for (name, base_opts) in variants {
        let mut cells = vec![name.to_string()];
        for &ratio in &ratios {
            let opts = CompressOpts { ratio, ..base_opts.clone() };
            let model = b.compress(&stats, &opts);
            cells.push(fmt_ppl(b.ppl(&model, Domain::Wiki2s)));
            eprint!(".");
        }
        t.row(cells);
        eprintln!(" {name} done");
    }
    common::emit(&t, "ablation_components");
}
