//! Table 2: PPL of the GQA model (LLaMA-3-8B analog) vs grouped layers n,
//! at 20% and 30% compression — SVD-LLM is n=1, Basis Sharing n=2..5.
//!
//! Expected shape: grouping n>1 *hurts* on GQA models (slimmed W_K/W_V
//! concatenations inflate group rank — paper §3.4).

#[path = "common/mod.rs"]
mod common;

use drank::compress::Method;
use drank::data::synlang::Domain;
use drank::report::{fmt_ppl, Table};

fn main() {
    let b = common::setup("gqa");
    let stats = b.calibrate(Domain::Wiki2s, false);

    let mut t = Table::new(
        "Table 2: GQA model PPL vs grouped layers (wiki2s)",
        &["Method", "Grouped layers", "20%", "30%"],
    );
    let mut eval_cfg = |method: Method, n: usize| -> Vec<String> {
        let mut cells = vec![method.name().to_string(), n.to_string()];
        for ratio in [0.2, 0.3] {
            let mut o = common::opts(method, ratio, n);
            o.gqa_policy = false; // show the raw effect of grouping
            let model = b.compress(&stats, &o);
            cells.push(fmt_ppl(b.ppl(&model, Domain::Wiki2s)));
            eprint!(".");
        }
        cells
    };
    t.row(eval_cfg(Method::SvdLlm, 1));
    let ns: Vec<usize> = if common::fast() { vec![2, 4] } else { vec![2, 3, 4, 5] };
    for n in ns {
        t.row(eval_cfg(Method::BasisSharing, n));
    }
    // and the paper's remedy: D-Rank with the n=1 GQA policy
    let mut cells = vec!["D-Rank (n=1 policy)".to_string(), "1".to_string()];
    for ratio in [0.2, 0.3] {
        let model = b.compress(&stats, &common::opts(Method::DRank, ratio, 4));
        cells.push(fmt_ppl(b.ppl(&model, Domain::Wiki2s)));
    }
    t.row(cells);
    eprintln!();
    common::emit(&t, "table2_gqa_grouping");
}
