//! §Perf microbenches: timings of every hot path on the compression and
//! serving sides. Used for the EXPERIMENTS.md §Perf before/after log.
//!
//! Own harness (criterion is unavailable offline): median of N timed
//! repetitions after a warmup, reported in a table. Three sections:
//!
//!  1. single-thread hot-path rows (the historical table),
//!  2. thread-scaling rows — the same op at 1 vs 4 threads, asserting the
//!     outputs are byte-identical while reporting the speedup (this now
//!     includes the batched serving forward, dense and factored),
//!  3. KV-cached generation rows — prefill 1→4T scaling (gated like the
//!     other serving rows) and cached decode vs full-prefix recompute of
//!     the same suffix, gated hard in-bench at ≥2x; decode throughput
//!     lands standalone in `runs/reports/generate_tiny.json`,
//!  4. a per-stage `CompressProfile` of a full artifact-free compression
//!     run on the `tiny` config,
//!  5. a factored-vs-dense-reconstructed ref-serving comparison on `tiny`
//!     (written standalone as `runs/reports/serve_factored_tiny.json`;
//!     the factored run must never touch the `Reconstruct` stage).
//!
//! Everything is folded into `runs/reports/BENCH_perf_hotpath.json` (the
//! bench trajectory artifact CI uploads; the per-stage profile is also
//! written standalone as `runs/reports/compress_profile_tiny.json`) and
//! gated two ways:
//!
//!  - absolute backstop: any op — or the summed eigen_sweep+eigen_sort
//!    stage, or the summed fwd+fwd_lowrank stage — slower than 3x its
//!    entry in the checked-in baseline
//!    `rust/benches/baselines/BENCH_perf_hotpath.json` fails the bench;
//!  - relative gate: the serving rows (`fwd_*`, `attn_tiny`) additionally
//!    compare their own 4-thread time against the 1-thread serial
//!    reference measured moments earlier in the same process — t4 above
//!    1.25x t1 fails. This replaces tight absolute ceilings (the
//!    checked-in numbers were hardware-blind estimates) with a
//!    machine-independent check, keeping the 3x absolute rule only as a
//!    wholesale-slowdown backstop. The packed-GEMM row gates the other
//!    direction: packed must beat the unpacked kernel by ≥1.3x on ≥2
//!    threads, measured against the in-bench unpacked run.
//!
//! `DRANK_PERF_BASELINE` overrides the baseline path. `DRANK_FAST=1`
//! lowers repetition counts only — sizes stay fixed so timings remain
//! comparable against the baseline.

#[path = "common/mod.rs"]
mod common;

use drank::calib::{CalibOpts, CalibStats};
use drank::compress::methods::all_type_svds;
use drank::compress::whiten::Whitener;
use drank::compress::{pipeline, Method};
use drank::data::synlang::Domain;
use drank::data::DataBundle;
use drank::linalg::svd::svd;
use drank::linalg::{cholesky_jitter, effective_rank};
use drank::model::{ModelConfig, Weights};
use drank::model::lowrank::Linear;
use drank::report::Table;
use drank::tensor::matmul::{gemm_f32, gemm_f32_packed, matmul_f32, matmul_f64, PackedMat};
use drank::tensor::{Mat32, MatF};
use drank::util::json::Json;
use drank::util::parallel::{set_threads, threads};
use drank::util::rng::Rng;
use drank::util::{profile, Timer};

fn median_time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    f(); // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f();
        times.push(t.millis());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[reps / 2]
}

fn randf(rng: &mut Rng, r: usize, c: usize) -> MatF {
    MatF::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
}

/// Time `op` at 1 and 4 threads; returns (t1_ms, t4_ms).
fn scale_pair<F: FnMut()>(mut op: F, reps: usize) -> (f64, f64) {
    set_threads(1);
    let t1 = median_time(&mut op, reps);
    set_threads(4);
    let t4 = median_time(&mut op, reps);
    (t1, t4)
}

fn main() {
    common::init_threads();
    let configured = threads();
    let reps = if common::fast() { 3 } else { 5 };
    let mut rng = Rng::new(0);
    let mut t = Table::new("perf: hot paths", &["op", "size", "median ms", "notes"]);
    // (name, t1_ms, t4_ms) rows for the JSON trajectory + regression gate
    let mut ops: Vec<(String, f64, f64)> = Vec::new();

    // f64 GEMM (whitening path)
    for &n in &[192usize, 512] {
        let a = randf(&mut rng, n, n);
        let b = randf(&mut rng, n, n);
        let ms = median_time(|| { let _ = matmul_f64(&a, &b); }, reps);
        let gflops = 2.0 * (n as f64).powi(3) / (ms / 1e3) / 1e9;
        t.row(vec![
            "matmul_f64".into(),
            format!("{n}x{n}x{n}"),
            format!("{ms:.2}"),
            format!("{gflops:.2} GFLOP/s"),
        ]);
    }
    // f32 GEMM (reconstruction path)
    {
        let n = 512;
        let a32 = Mat32::from_vec(n, n, (0..n * n).map(|i| (i % 13) as f32).collect());
        let b32 = a32.clone();
        let ms = median_time(|| { let _ = matmul_f32(&a32, &b32); }, reps);
        let gflops = 2.0 * (n as f64).powi(3) / (ms / 1e3) / 1e9;
        t.row(vec![
            "matmul_f32".into(),
            format!("{n}x{n}x{n}"),
            format!("{ms:.2}"),
            format!("{gflops:.2} GFLOP/s"),
        ]);
    }
    // SVD via Gram eigen — the compression bottleneck
    for &(m, n) in &[(192usize, 384usize), (192, 768), (512, 192)] {
        let a = randf(&mut rng, m, n);
        let ms = median_time(|| { let _ = svd(&a); }, 3);
        t.row(vec!["svd".into(), format!("{m}x{n}"), format!("{ms:.2}"), "jacobi-gram".into()]);
    }
    // Cholesky + triangular solve (whitening)
    {
        let n = 512;
        let x = randf(&mut rng, n + 32, n);
        let mut g = x.t_matmul(&x);
        g.scale(1.0 / (n + 32) as f64);
        let ms = median_time(|| { let _ = cholesky_jitter(&g); }, reps);
        t.row(vec!["cholesky".into(), format!("{n}x{n}"), format!("{ms:.2}"), "".into()]);
        let wh = Whitener::from_gram(&g);
        let w = randf(&mut rng, n, 192);
        let ms = median_time(|| { let _ = wh.unapply(&wh.apply(&w)); }, reps);
        t.row(vec!["whiten+unwhiten".into(), format!("{n}x192"), format!("{ms:.2}"), "".into()]);
    }
    // effective rank
    {
        let s: Vec<f64> = (0..512).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let ms = median_time(|| { let _ = effective_rank(&s); }, 50);
        t.row(vec!["effective_rank".into(), "512".into(), format!("{ms:.4}"), "".into()]);
    }

    // thread scaling: same op at 1 vs 4 threads, byte-identical outputs
    {
        let n = 512;
        let a = randf(&mut rng, n, n);
        let b = randf(&mut rng, n, n);
        set_threads(1);
        let want64 = matmul_f64(&a, &b);
        set_threads(4);
        assert_eq!(matmul_f64(&a, &b).data, want64.data, "matmul_f64 not thread-invariant");
        let (t1, t4) = scale_pair(|| { let _ = matmul_f64(&a, &b); }, reps);
        t.row(vec![
            "matmul_f64".into(),
            format!("{n}x{n}x{n} @1->4T"),
            format!("{t1:.2} -> {t4:.2}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("matmul_f64_512".into(), t1, t4));

        let a32 = a.to_f32();
        let b32 = b.to_f32();
        set_threads(1);
        let want32 = matmul_f32(&a32, &b32);
        set_threads(4);
        assert_eq!(matmul_f32(&a32, &b32).data, want32.data, "matmul_f32 not thread-invariant");
        let (t1, t4) = scale_pair(|| { let _ = matmul_f32(&a32, &b32); }, reps);
        t.row(vec![
            "matmul_f32".into(),
            format!("{n}x{n}x{n} @1->4T"),
            format!("{t1:.2} -> {t4:.2}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("matmul_f32_512".into(), t1, t4));

        set_threads(1);
        let want_t = a.t_matmul(&b);
        set_threads(4);
        assert_eq!(a.t_matmul(&b).data, want_t.data, "t_matmul not thread-invariant");
        let (t1, t4) = scale_pair(|| { let _ = a.t_matmul(&b); }, reps);
        t.row(vec![
            "t_matmul".into(),
            format!("{n}x{n} @1->4T"),
            format!("{t1:.2} -> {t4:.2}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("t_matmul_512".into(), t1, t4));

        // packed-panel GEMM on the same operands: byte-identical to the
        // unpacked kernel at every thread count, and the block-major
        // layout must actually pay for itself — ≥1.3x over unpacked on at
        // least one of 2/4 threads, gated against the in-bench unpacked
        // run rather than a hardware-blind absolute ceiling
        let bits32 = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        let bp = PackedMat::pack(&b32.data, n, n);
        set_threads(1);
        let want_p = bits32(&gemm_f32(&a32.data, n, n, &b32.data, n));
        assert_eq!(
            bits32(&gemm_f32_packed(&a32.data, n, n, &bp)),
            want_p,
            "packed GEMM != unpacked bits at 1 thread"
        );
        set_threads(4);
        assert_eq!(
            bits32(&gemm_f32_packed(&a32.data, n, n, &bp)),
            want_p,
            "packed GEMM not thread-invariant"
        );
        let (t1, t4) = scale_pair(|| { let _ = gemm_f32_packed(&a32.data, n, n, &bp); }, reps);
        t.row(vec![
            "gemm_packed".into(),
            format!("{n}x{n}x{n} @1->4T"),
            format!("{t1:.2} -> {t4:.2}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("gemm_packed_512".into(), t1, t4));
        let mut best_ratio = 0.0f64;
        for th in [2usize, 4] {
            set_threads(th);
            let unpacked =
                median_time(|| { let _ = gemm_f32(&a32.data, n, n, &b32.data, n); }, reps);
            let packed = median_time(|| { let _ = gemm_f32_packed(&a32.data, n, n, &bp); }, reps);
            best_ratio = best_ratio.max(unpacked / packed.max(1e-9));
        }
        t.row(vec![
            "gemm_packed/unpacked".into(),
            format!("{n}x{n}x{n} @2,4T"),
            format!("{best_ratio:.2}x"),
            "pack payoff (gate: >=1.3x)".into(),
        ]);
        assert!(
            best_ratio >= 1.3,
            "packed GEMM only {best_ratio:.2}x over unpacked at {n}x{n} (need >=1.3x on 2 or 4 threads)"
        );

        // fused factored path (x·B)·C through one scratch buffer vs the
        // legacy two-allocation unpacked path — byte-identical, then timed
        use std::sync::OnceLock;
        let k = 64;
        let rows = 192;
        let bm = randf(&mut rng, n, k).to_f32();
        let cm = randf(&mut rng, k, n).to_f32();
        let x: Vec<f32> = randf(&mut rng, rows, n).to_f32().data;
        let bslot: OnceLock<PackedMat> = OnceLock::new();
        let cslot: OnceLock<PackedMat> = OnceLock::new();
        let fused = Linear::Factored { b: &bm, c: &cm, pack: Some((&bslot, &cslot)) };
        let plain = Linear::Factored { b: &bm, c: &cm, pack: None };
        set_threads(1);
        let want_f = bits32(&plain.matmul(&x, rows));
        assert_eq!(bits32(&fused.matmul(&x, rows)), want_f, "fused factored != plain bits");
        set_threads(4);
        assert_eq!(
            bits32(&fused.matmul(&x, rows)),
            want_f,
            "fused factored not thread-invariant"
        );
        let (t1, t4) = scale_pair(|| { let _ = fused.matmul(&x, rows); }, reps);
        t.row(vec![
            "fused_factored".into(),
            format!("{rows}x{n}·({n}x{k}·{k}x{n}) @1->4T"),
            format!("{t1:.2} -> {t4:.2}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("fused_factored_512".into(), t1, t4));
    }
    // grouped SVD sweep (the planning phase of a full compress) on the `m`
    // config with synthetic stats — no checkpoint or artifacts needed
    {
        let cfg = ModelConfig::by_name("m").unwrap();
        let w = Weights::init(cfg, 11);
        let stats = CalibStats::synthetic(&cfg, 12);
        let o = common::opts(Method::DRank, 0.3, 2);
        let (t1, t4) = scale_pair(|| { let _ = all_type_svds(&w, &stats, &o); }, 3);
        t.row(vec![
            "all_type_svds".into(),
            "m, drank n=2 @1->4T".into(),
            format!("{t1:.1} -> {t4:.1}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("all_type_svds_m".into(), t1, t4));
    }
    // blocked Jacobi eigensolve on a 384x384 Gram (the issue's headline
    // size): byte-identical `Eigen` output at 1 vs 4 threads, speedup row
    {
        use drank::linalg::eigen::{jacobi_eigen, jacobi_eigen_blocked};
        let n = 384;
        let x = randf(&mut rng, n + 16, n);
        let mut g = x.t_matmul(&x);
        g.scale(1.0 / (n + 16) as f64);
        set_threads(1);
        let e1 = jacobi_eigen_blocked(&g);
        set_threads(4);
        let e4 = jacobi_eigen_blocked(&g);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&e1.values), bits(&e4.values), "eigenvalues not thread-invariant");
        assert_eq!(
            bits(&e1.vectors.data),
            bits(&e4.vectors.data),
            "eigenvectors not thread-invariant"
        );
        let (t1, t4) = scale_pair(|| { let _ = jacobi_eigen_blocked(&g); }, 3);
        t.row(vec![
            "eigen_blocked".into(),
            format!("{n}x{n} @1->4T"),
            format!("{t1:.1} -> {t4:.1}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("eigen_blocked_384".into(), t1, t4));
        // serial reference on the same Gram, for the blocked-vs-serial row
        set_threads(1);
        let ms = median_time(|| { let _ = jacobi_eigen(&g); }, 3);
        t.row(vec![
            "eigen_serial".into(),
            format!("{n}x{n}"),
            format!("{ms:.1}"),
            "cyclic reference".into(),
        ]);
    }
    // batched serving forward on `tiny`: dense (y = x·W) vs factored
    // ((x·B)·C), both byte-identical across thread counts
    {
        use drank::model::fwd;
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 5);
        let stats = CalibStats::synthetic(&cfg, 6);
        let o = common::opts(Method::DRank, 0.3, 2);
        let (model, _) = drank::compress::methods::compress(&w, &stats, &o).unwrap();
        let toks: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        set_threads(1);
        let want_d = bits(&fwd::nll(&w, &toks, cfg.batch, cfg.seq));
        let want_f = bits(&fwd::nll_model(&model, &toks, cfg.batch, cfg.seq));
        set_threads(4);
        assert_eq!(
            bits(&fwd::nll(&w, &toks, cfg.batch, cfg.seq)),
            want_d,
            "dense forward not thread-invariant"
        );
        assert_eq!(
            bits(&fwd::nll_model(&model, &toks, cfg.batch, cfg.seq)),
            want_f,
            "factored forward not thread-invariant"
        );
        let (t1, t4) = scale_pair(|| { let _ = fwd::nll(&w, &toks, cfg.batch, cfg.seq); }, reps);
        t.row(vec![
            "fwd_dense".into(),
            format!("tiny {}x{} @1->4T", cfg.batch, cfg.seq),
            format!("{t1:.1} -> {t4:.1}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("fwd_dense_tiny".into(), t1, t4));
        let (t1, t4) =
            scale_pair(|| { let _ = fwd::nll_model(&model, &toks, cfg.batch, cfg.seq); }, reps);
        t.row(vec![
            "fwd_factored".into(),
            format!("tiny drank 0.3 {}x{} @1->4T", cfg.batch, cfg.seq),
            format!("{t1:.1} -> {t4:.1}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("fwd_factored_tiny".into(), t1, t4));

        // the attn stage in isolation: the blocked streaming-softmax
        // kernel records wall time of the attention region once per layer
        // call, so the per-forward cost falls out of profile-counter
        // deltas without separating it from the surrounding GEMMs by hand
        let attn_ms = |th: usize, n: usize| {
            set_threads(th);
            let _ = fwd::nll(&w, &toks, cfg.batch, cfg.seq); // warmup
            let before = profile::snapshot(0.0).stage_ms("attn");
            for _ in 0..n {
                let _ = fwd::nll(&w, &toks, cfg.batch, cfg.seq);
            }
            (profile::snapshot(0.0).stage_ms("attn") - before) / n as f64
        };
        let areps = reps * 4; // cheap op; extra reps steady the mean
        let (t1, t4) = (attn_ms(1, areps), attn_ms(4, areps));
        t.row(vec![
            "attn".into(),
            format!("tiny {}x{} @1->4T", cfg.batch, cfg.seq),
            format!("{t1:.3} -> {t4:.3}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("attn_tiny".into(), t1, t4));
    }
    // KV-cached generation on `tiny` at seq 96: prefill thread-scaling
    // (same relative gate as the other serving rows) and cached decode vs
    // recomputing the full prefix for every emitted token — the whole
    // reason the cache exists, gated hard in-bench at ≥2x
    {
        use drank::model::fwd;
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 5);
        let (prompt_len, total) = (64usize, 96usize);
        let toks: Vec<i32> = (0..total).map(|i| (i % cfg.vocab) as i32).collect();
        let suffix = &toks[prompt_len..];
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();

        // prefill is the batched forward plus cache writes: byte-identical
        // across thread counts, and expected to scale like fwd_dense
        set_threads(1);
        let want_p = {
            let mut st = fwd::DecodeState::new(&cfg, total);
            bits(&fwd::prefill(&w, &toks[..prompt_len], &mut st))
        };
        set_threads(4);
        {
            let mut st = fwd::DecodeState::new(&cfg, total);
            assert_eq!(
                bits(&fwd::prefill(&w, &toks[..prompt_len], &mut st)),
                want_p,
                "prefill not thread-invariant"
            );
        }
        let (t1, t4) = scale_pair(
            || {
                let mut st = fwd::DecodeState::new(&cfg, total);
                let _ = fwd::prefill(&w, &toks[..prompt_len], &mut st);
            },
            reps,
        );
        t.row(vec![
            "prefill".into(),
            format!("tiny 1x{prompt_len} @1->4T"),
            format!("{t1:.2} -> {t4:.2}"),
            format!("{:.2}x", t1 / t4.max(1e-9)),
        ]);
        ops.push(("prefill_tiny".into(), t1, t4));

        // cached: one prefill + 32 single-token decode steps; recompute:
        // one full batched forward over the whole growing prefix per token
        // (what serving the suffix costs without a KV cache)
        set_threads(1);
        let cached_ms = median_time(
            || {
                let mut st = fwd::DecodeState::new(&cfg, total);
                let _ = fwd::prefill(&w, &toks[..prompt_len], &mut st);
                for &tok in suffix {
                    let _ = fwd::decode_step(&w, tok, &mut st);
                }
            },
            reps,
        );
        let recompute_ms = median_time(
            || {
                for n in prompt_len..total {
                    let mut st = fwd::DecodeState::new(&cfg, n + 1);
                    let _ = fwd::prefill(&w, &toks[..=n], &mut st);
                }
            },
            reps,
        );
        let speedup = recompute_ms / cached_ms.max(1e-9);
        t.row(vec![
            "decode(cached/recompute)".into(),
            format!("tiny {prompt_len}+{} @1T", suffix.len()),
            format!("{cached_ms:.2} vs {recompute_ms:.2}"),
            format!("{speedup:.2}x (gate: >=2x)"),
        ]);
        ops.push(("decode_tiny".into(), cached_ms, cached_ms));
        assert!(
            speedup >= 2.0,
            "cached decode only {speedup:.2}x over full-prefix recompute at seq {total} (need >=2x)"
        );

        // decode-only throughput (prefill excluded): the tokens/sec number
        // the §Decode docs quote
        let mut decode_times = Vec::with_capacity(reps);
        for rep in 0..=reps {
            let mut st = fwd::DecodeState::new(&cfg, total);
            let _ = fwd::prefill(&w, &toks[..prompt_len], &mut st);
            let timer = Timer::start();
            for &tok in suffix {
                let _ = fwd::decode_step(&w, tok, &mut st);
            }
            if rep > 0 {
                // first pass is warmup (pack caches, branch predictors)
                decode_times.push(timer.millis());
            }
        }
        decode_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let decode_ms = decode_times[decode_times.len() / 2];
        let decode_tps = suffix.len() as f64 / (decode_ms / 1e3);
        t.row(vec![
            "decode".into(),
            format!("tiny {} steps @1T", suffix.len()),
            format!("{decode_ms:.2}"),
            format!("{decode_tps:.0} tok/s"),
        ]);
        std::fs::create_dir_all("runs/reports").expect("mkdir runs/reports");
        std::fs::write(
            "runs/reports/generate_tiny.json",
            Json::obj(vec![
                ("model", Json::str("tiny")),
                ("prompt_len", Json::num(prompt_len as f64)),
                ("new_tokens", Json::num(suffix.len() as f64)),
                ("decode_ms", Json::num(decode_ms)),
                ("decode_tps", Json::num(decode_tps)),
                ("cached_ms", Json::num(cached_ms)),
                ("recompute_ms", Json::num(recompute_ms)),
                ("cached_speedup", Json::num(speedup)),
                ("prefill_t1_ms", Json::num(t1)),
                ("prefill_t4_ms", Json::num(t4)),
            ])
            .emit(),
        )
        .expect("write generate_tiny.json");
        eprintln!("[bench] wrote runs/reports/generate_tiny.json");
    }
    set_threads(configured);

    // factored vs dense-reconstructed ref serving on `tiny`: same requests
    // through `spawn_model_server`, once on the factors (which must never
    // call the Reconstruct stage) and once on a dense passthrough of the
    // reconstructed weights
    {
        use drank::coordinator::{spawn_model_server, ServerOpts};
        use drank::model::lowrank::CompressedModel;
        use drank::util::profile::{stage_calls, Stage};
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 5);
        let stats = CalibStats::synthetic(&cfg, 6);
        let o = common::opts(Method::DRank, 0.3, 2);
        let (model, _) = drank::compress::methods::compress(&w, &stats, &o).unwrap();
        let ratio = model.achieved_ratio();
        let dense = CompressedModel::dense_passthrough(model.to_dense());
        let requests = if common::fast() { 16 } else { 48 };
        let run = |m: CompressedModel| {
            let recon0 = stage_calls(Stage::Reconstruct);
            let server = spawn_model_server(
                m,
                cfg.batch,
                cfg.seq,
                "ref",
                ServerOpts { workers: 2, ..Default::default() },
            )
            .expect("spawn ref server");
            let handles: Vec<_> = (0..requests)
                .map(|i| {
                    let c = server.client();
                    let seq = cfg.seq;
                    std::thread::spawn(move || {
                        c.score(vec![(i % 250 + 1) as u32; seq]).expect("score")
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let m = server.shutdown().expect("shutdown");
            (m.throughput_tps(), stage_calls(Stage::Reconstruct) - recon0)
        };
        let (tps_f, recon_f) = run(model);
        let (tps_d, recon_d) = run(dense);
        assert_eq!(recon_f, 0, "factored ref serving called the Reconstruct stage");
        t.row(vec![
            "serve(ref,factored)".into(),
            format!("tiny drank {ratio:.2}, {requests} req"),
            format!("{tps_f:.0} tok/s"),
            "serves factors directly".into(),
        ]);
        t.row(vec![
            "serve(ref,dense)".into(),
            format!("tiny reconstructed, {requests} req"),
            format!("{tps_d:.0} tok/s"),
            "to_dense() baseline".into(),
        ]);
        std::fs::create_dir_all("runs/reports").expect("mkdir runs/reports");
        std::fs::write(
            "runs/reports/serve_factored_tiny.json",
            Json::obj(vec![
                ("model", Json::str("tiny")),
                ("method", Json::str("drank")),
                ("ratio", Json::num(ratio)),
                ("requests", Json::num(requests as f64)),
                ("factored_tps", Json::num(tps_f)),
                ("dense_tps", Json::num(tps_d)),
                ("factored_reconstruct_calls", Json::num(recon_f as f64)),
                ("dense_reconstruct_calls", Json::num(recon_d as f64)),
            ])
            .emit(),
        )
        .expect("write serve_factored_tiny.json");
        eprintln!("[bench] wrote runs/reports/serve_factored_tiny.json");
    }

    // per-stage profile: artifact-free end-to-end compression on `tiny`
    let prof = {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 7);
        let data = DataBundle::build(cfg.vocab, 3, 0.02);
        let copts = CalibOpts { batches: 4, ..Default::default() };
        let o = common::opts(Method::DRank, 0.3, 2);
        profile::reset();
        let timer = Timer::start();
        let (model, _) =
            pipeline::compress_model_reference(&w, &data, &copts, &o).expect("ref compress");
        let _ = model.to_dense(); // exercise the Reconstruct stage
        let prof = profile::snapshot(timer.millis());
        print!("{}", prof.render());
        // the same per-model profile artifact `drank compress` writes, so
        // the CI perf job can upload one without needing a checkpoint
        std::fs::create_dir_all("runs/reports").expect("mkdir runs/reports");
        std::fs::write(
            "runs/reports/compress_profile_tiny.json",
            Json::obj(vec![
                ("model", Json::str("tiny")),
                ("method", Json::str("drank")),
                ("ratio", Json::num(o.ratio)),
                ("profile", prof.to_json()),
            ])
            .emit(),
        )
        .expect("write compress_profile_tiny.json");
        eprintln!("[bench] wrote runs/reports/compress_profile_tiny.json");
        prof
    };

    // end-to-end: compress (drank) + one PPL batch + graph compile+exec,
    // only if a checkpoint exists (perf bench also runs standalone pre-train)
    if std::path::Path::new("runs/m/model.bin").exists() {
        let b = common::setup("m");
        let stats = b.calibrate(Domain::Wiki2s, false);
        let opts = common::opts(Method::DRank, 0.3, 2);
        let ms = median_time(
            || { let _ = drank::compress::methods::compress(&b.weights, &stats, &opts); },
            3,
        );
        t.row(vec!["compress(drank,m)".into(), "ratio 0.3 n=2".into(), format!("{ms:.1}"), "full model".into()]);

        let (model, _) = drank::compress::methods::compress(&b.weights, &stats, &opts).unwrap();
        let cfg = model.config();
        let tcomp = Timer::start();
        let fwd = drank::graph::compile_forward(&b.engine.rt, &model, cfg.batch, cfg.seq).unwrap();
        let compile_ms = tcomp.millis();
        let toks: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
        let ms = median_time(|| { let _ = fwd.nll(&toks).unwrap(); }, 10);
        let tokens = (cfg.batch * cfg.seq) as f64;
        t.row(vec![
            "graph compile".into(),
            "drank 0.3".into(),
            format!("{compile_ms:.1}"),
            "once per allocation".into(),
        ]);
        t.row(vec![
            "graph exec".into(),
            format!("{}x{}", cfg.batch, cfg.seq),
            format!("{ms:.2}"),
            format!("{:.0} tok/s", tokens / (ms / 1e3)),
        ]);
    } else {
        eprintln!("[perf] no m checkpoint; skipping end-to-end rows");
    }

    common::emit(&t, "perf_hotpath");

    // bench-trajectory JSON + regression gate
    let ops_json = Json::Obj(
        ops.iter()
            .map(|(name, t1, t4)| {
                (
                    name.clone(),
                    Json::obj(vec![
                        ("t1_ms", Json::num(*t1)),
                        ("t4_ms", Json::num(*t4)),
                        ("speedup", Json::num(t1 / t4.max(1e-9))),
                    ]),
                )
            })
            .collect(),
    );
    let out = Json::obj(vec![
        ("bench", Json::str("perf_hotpath")),
        ("fast", Json::Bool(common::fast())),
        ("threads_default", Json::num(configured as f64)),
        ("ops", ops_json),
        ("profile", prof.to_json()),
    ]);
    std::fs::create_dir_all("runs/reports").expect("mkdir runs/reports");
    std::fs::write("runs/reports/BENCH_perf_hotpath.json", out.emit())
        .expect("write BENCH_perf_hotpath.json");
    eprintln!("[bench] wrote runs/reports/BENCH_perf_hotpath.json");

    let baseline_path = std::env::var("DRANK_PERF_BASELINE")
        .unwrap_or_else(|_| "rust/benches/baselines/BENCH_perf_hotpath.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Err(_) => eprintln!("[bench] no baseline at {baseline_path}; skipping regression gate"),
        Ok(raw) => {
            let base = Json::parse(&raw).expect("parse perf baseline");
            let mut failed = false;
            for (name, t1, t4) in &ops {
                let Some(b) = base.get("ops").and_then(|o| o.get(name)) else {
                    eprintln!("[bench] {name}: not in baseline, skipping");
                    continue;
                };
                for (key, got) in [("t1_ms", *t1), ("t4_ms", *t4)] {
                    let Some(want) = b.get(key).and_then(|v| v.as_f64()) else { continue };
                    if got > want * 3.0 {
                        eprintln!(
                            "[bench] REGRESSION {name}.{key}: {got:.2} ms > 3x baseline {want:.2} ms"
                        );
                        failed = true;
                    }
                }
            }
            // serving rows: relative gate against this run's own serial
            // reference — machine-independent, unlike the estimated
            // absolute ceilings (which stay only as the 3x backstop above)
            for (name, t1, t4) in &ops {
                if !(name.starts_with("fwd_")
                    || name.as_str() == "attn_tiny"
                    || name.as_str() == "prefill_tiny")
                {
                    continue;
                }
                if *t4 > t1 * 1.25 {
                    eprintln!(
                        "[bench] REGRESSION {name}: 4-thread {t4:.2} ms > 1.25x own 1-thread reference {t1:.2} ms"
                    );
                    failed = true;
                }
            }
            // eigen-stage gate: the summed eigen_sweep+eigen_sort cpu-ms of
            // the tiny-config profile, same 3x rule as the op rows
            if let Some(want) =
                base.get("profile").and_then(|p| p.get("eigen_cpu_ms")).and_then(|v| v.as_f64())
            {
                let got = prof.eigen_ms();
                if got > want * 3.0 {
                    eprintln!(
                        "[bench] REGRESSION eigen stage: {got:.2} cpu-ms > 3x baseline {want:.2} cpu-ms"
                    );
                    failed = true;
                }
            } else {
                eprintln!("[bench] baseline has no profile.eigen_cpu_ms; skipping eigen gate");
            }
            // forward-stage gate: summed fwd+fwd_lowrank cpu-ms of the same
            // profile (the reference calibration inside the tiny compress
            // runs the batched forward), same 3x rule
            if let Some(want) =
                base.get("profile").and_then(|p| p.get("fwd_cpu_ms")).and_then(|v| v.as_f64())
            {
                let got = prof.fwd_ms();
                if got > want * 3.0 {
                    eprintln!(
                        "[bench] REGRESSION fwd stage: {got:.2} cpu-ms > 3x baseline {want:.2} cpu-ms"
                    );
                    failed = true;
                }
            } else {
                eprintln!("[bench] baseline has no profile.fwd_cpu_ms; skipping fwd gate");
            }
            if failed {
                std::process::exit(1);
            }
            eprintln!("[bench] regression gate passed (baseline {baseline_path})");
        }
    }
}
