//! §Perf microbenches: timings of every hot path on the compression and
//! serving sides. Used for the EXPERIMENTS.md §Perf before/after log.
//!
//! Own harness (criterion is unavailable offline): median of N timed
//! repetitions after a warmup, reported in a table.

#[path = "common/mod.rs"]
mod common;

use drank::compress::whiten::Whitener;
use drank::linalg::svd::svd;
use drank::linalg::{cholesky_jitter, effective_rank};
use drank::report::Table;
use drank::tensor::matmul::{matmul_f32, matmul_f64};
use drank::tensor::{Mat32, MatF};
use drank::util::rng::Rng;
use drank::util::Timer;

fn median_time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    f(); // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f();
        times.push(t.millis());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[reps / 2]
}

fn randf(rng: &mut Rng, r: usize, c: usize) -> MatF {
    MatF::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
}

fn main() {
    let mut rng = Rng::new(0);
    let mut t = Table::new("perf: hot paths", &["op", "size", "median ms", "notes"]);

    // f64 GEMM (whitening path)
    for &n in &[192usize, 512] {
        let a = randf(&mut rng, n, n);
        let b = randf(&mut rng, n, n);
        let ms = median_time(|| { let _ = matmul_f64(&a, &b); }, 5);
        let gflops = 2.0 * (n as f64).powi(3) / (ms / 1e3) / 1e9;
        t.row(vec![
            "matmul_f64".into(),
            format!("{n}x{n}x{n}"),
            format!("{ms:.2}"),
            format!("{gflops:.2} GFLOP/s"),
        ]);
    }
    // f32 GEMM (reconstruction path)
    {
        let n = 512;
        let a32 = Mat32::from_vec(n, n, (0..n * n).map(|i| (i % 13) as f32).collect());
        let b32 = a32.clone();
        let ms = median_time(|| { let _ = matmul_f32(&a32, &b32); }, 5);
        let gflops = 2.0 * (n as f64).powi(3) / (ms / 1e3) / 1e9;
        t.row(vec![
            "matmul_f32".into(),
            format!("{n}x{n}x{n}"),
            format!("{ms:.2}"),
            format!("{gflops:.2} GFLOP/s"),
        ]);
    }
    // SVD via Gram eigen — the compression bottleneck
    for &(m, n) in &[(192usize, 384usize), (192, 768), (512, 192)] {
        let a = randf(&mut rng, m, n);
        let ms = median_time(|| { let _ = svd(&a); }, 3);
        t.row(vec!["svd".into(), format!("{m}x{n}"), format!("{ms:.2}"), "jacobi-gram".into()]);
    }
    // Cholesky + triangular solve (whitening)
    {
        let n = 512;
        let x = randf(&mut rng, n + 32, n);
        let mut g = x.t_matmul(&x);
        g.scale(1.0 / (n + 32) as f64);
        let ms = median_time(|| { let _ = cholesky_jitter(&g); }, 5);
        t.row(vec!["cholesky".into(), format!("{n}x{n}"), format!("{ms:.2}"), "".into()]);
        let wh = Whitener::from_gram(&g);
        let w = randf(&mut rng, n, 192);
        let ms = median_time(|| { let _ = wh.unapply(&wh.apply(&w)); }, 5);
        t.row(vec!["whiten+unwhiten".into(), format!("{n}x192"), format!("{ms:.2}"), "".into()]);
    }
    // effective rank
    {
        let s: Vec<f64> = (0..512).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let ms = median_time(|| { let _ = effective_rank(&s); }, 50);
        t.row(vec!["effective_rank".into(), "512".into(), format!("{ms:.4}"), "".into()]);
    }

    // end-to-end: compress (drank) + one PPL batch + graph compile+exec,
    // only if a checkpoint exists (perf bench also runs standalone pre-train)
    if std::path::Path::new("runs/m/model.bin").exists() {
        let b = common::setup("m");
        let stats = b.calibrate(drank::data::synlang::Domain::Wiki2s, false);
        let opts = common::opts(drank::compress::Method::DRank, 0.3, 2);
        let ms = median_time(
            || { let _ = drank::compress::methods::compress(&b.weights, &stats, &opts); },
            3,
        );
        t.row(vec!["compress(drank,m)".into(), "ratio 0.3 n=2".into(), format!("{ms:.1}"), "full model".into()]);

        let (model, _) = drank::compress::methods::compress(&b.weights, &stats, &opts).unwrap();
        let cfg = model.config();
        let tcomp = Timer::start();
        let fwd = drank::graph::compile_forward(&b.engine.rt, &model, cfg.batch, cfg.seq).unwrap();
        let compile_ms = tcomp.millis();
        let toks: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
        let ms = median_time(|| { let _ = fwd.nll(&toks).unwrap(); }, 10);
        let tokens = (cfg.batch * cfg.seq) as f64;
        t.row(vec![
            "graph compile".into(),
            "drank 0.3".into(),
            format!("{compile_ms:.1}"),
            "once per allocation".into(),
        ]);
        t.row(vec![
            "graph exec".into(),
            format!("{}x{}", cfg.batch, cfg.seq),
            format!("{ms:.2}"),
            format!("{:.0} tok/s", tokens / (ms / 1e3)),
        ]);
    } else {
        eprintln!("[perf] no m checkpoint; skipping end-to-end rows");
    }

    common::emit(&t, "perf_hotpath");
}
