//! Table 3: the main grid — PPL on three datasets + 7 zero-shot suites,
//! six methods × ratios 20–50%, n=2 groups, wiki2s calibration.
//!
//! Expected shape: D-Rank <= Basis Sharing <= SVD-LLM <= ASVD << FWSVD/SVD
//! in PPL at every ratio, with graceful degradation as ratio grows.

#[path = "common/mod.rs"]
mod common;

use drank::data::synlang::Domain;
use drank::data::tasks::ALL_SUITES;
use drank::report::{fmt_acc, fmt_ppl, Table};

fn main() {
    let b = common::setup("m");
    // one calibration pass serves every method; FWSVD additionally needs
    // Fisher rows, so collect them in the same pass
    let stats = b.calibrate(Domain::Wiki2s, true);

    let mut header = vec!["Ratio", "Method", "wiki2s↓", "ptbs↓", "c4s↓"];
    header.extend(ALL_SUITES.iter().map(|s| s.name()));
    header.push("Average*↑");
    let mut t = Table::new("Table 3: PPL + zero-shot, methods x ratios (m)", &header);

    // original row
    {
        let mut cells = vec!["0%".to_string(), "Original".to_string()];
        for d in [Domain::Wiki2s, Domain::Ptbs, Domain::C4s] {
            cells.push(fmt_ppl(b.ppl_dense(&b.weights, d)));
        }
        let (accs, avg) = b.zero_shot(&b.weights);
        cells.extend(accs.iter().map(|(_, a)| fmt_acc(*a)));
        cells.push(fmt_acc(avg));
        t.row(cells);
    }

    let ratios: Vec<f64> = if common::fast() { vec![0.2, 0.4] } else { vec![0.2, 0.3, 0.4, 0.5] };
    for &ratio in &ratios {
        for method in common::all_methods() {
            let model = b.compress(&stats, &common::opts(method, ratio, 2));
            let dense = model.to_dense();
            let mut cells = vec![format!("{:.0}%", ratio * 100.0), method.name().to_string()];
            for d in [Domain::Wiki2s, Domain::Ptbs, Domain::C4s] {
                cells.push(fmt_ppl(b.ppl_dense(&dense, d)));
            }
            let (accs, avg) = b.zero_shot(&dense);
            cells.extend(accs.iter().map(|(_, a)| fmt_acc(*a)));
            cells.push(fmt_acc(avg));
            t.row(cells);
            eprint!(".");
        }
        eprintln!(" ratio {ratio} done");
    }
    common::emit(&t, "table3_main");
}
