//! Figure 3: LoRA fine-tuning PPL of the compressed model, ratios 20–50%,
//! for SVD-LLM / Basis Sharing / D-Rank.
//!
//! Expected shape: LoRA recovers part of the compression loss for every
//! method; D-Rank stays lowest and the gap widens with the ratio.

#[path = "common/mod.rs"]
mod common;

use drank::compress::Method;
use drank::data::synlang::Domain;
use drank::lora::{finetune, LoraOpts};
use drank::report::{fmt_ppl, Table};

fn main() {
    let b = common::setup("m");
    let stats = b.calibrate(Domain::Wiki2s, false);
    let ratios: Vec<f64> = if common::fast() { vec![0.2, 0.4] } else { vec![0.2, 0.3, 0.4, 0.5] };
    let steps = common::env_usize("DRANK_LORA_STEPS", 25);

    let mut header = vec!["Method".to_string()];
    for &r in &ratios {
        header.push(format!("{:.0}%", r * 100.0));
        header.push(format!("{:.0}%+LoRA", r * 100.0));
    }
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Figure 3: LoRA fine-tuning PPL (m, wiki2s)", &hrefs);

    for method in [Method::SvdLlm, Method::BasisSharing, Method::DRank] {
        let mut cells = vec![method.name().to_string()];
        for &ratio in &ratios {
            let model = b.compress(&stats, &common::opts(method, ratio, 2));
            let before = b.ppl(&model, Domain::Wiki2s);
            let log = finetune(
                &b.engine,
                &model,
                &b.data,
                &LoraOpts { steps, ..Default::default() },
            )
            .expect("lora finetune");
            let after = b.ppl_dense(&log.merged, Domain::Wiki2s);
            cells.push(fmt_ppl(before));
            cells.push(fmt_ppl(after));
            eprint!(".");
        }
        t.row(cells);
        eprintln!(" {} done", method.name());
    }
    common::emit(&t, "fig3_lora");
}
