//! Table 4: the GQA model (LLaMA-3-8B analog) at 20% compression —
//! PPL (wiki2s, c4s) + zero-shot vs all baselines. Basis Sharing uses n=5
//! as in the paper; D-Rank applies its n=1 GQA policy.

#[path = "common/mod.rs"]
mod common;

use drank::compress::Method;
use drank::data::synlang::Domain;
use drank::data::tasks::ALL_SUITES;
use drank::report::{fmt_acc, fmt_ppl, Table};

fn main() {
    let b = common::setup("gqa");
    let stats = b.calibrate(Domain::Wiki2s, true);

    let mut header = vec!["Method", "wiki2s↓", "c4s↓"];
    header.extend(ALL_SUITES.iter().map(|s| s.name()));
    header.push("Average*↑");
    let mut t = Table::new("Table 4: GQA model @ 20%", &header);

    let mut row = |name: &str, dense: &drank::model::Weights| {
        let mut cells = vec![name.to_string()];
        cells.push(fmt_ppl(b.ppl_dense(dense, Domain::Wiki2s)));
        cells.push(fmt_ppl(b.ppl_dense(dense, Domain::C4s)));
        let (accs, avg) = b.zero_shot(dense);
        cells.extend(accs.iter().map(|(_, a)| fmt_acc(*a)));
        cells.push(fmt_acc(avg));
        t.row(cells);
        eprint!(".");
    };

    row("Original", &b.weights.clone());
    for method in common::all_methods() {
        // paper: basis sharing n=5 on LLaMA-3; others n=1-equivalent
        let n = if method == Method::BasisSharing { 5 } else { 2 };
        let model = b.compress(&stats, &common::opts(method, 0.2, n));
        row(method.name(), &model.to_dense());
    }
    eprintln!();
    common::emit(&t, "table4_gqa_main");
}
