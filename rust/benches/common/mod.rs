//! Shared setup for the paper-table benches.
//!
//! Each bench is a `harness = false` binary that regenerates one table or
//! figure from the paper (criterion is unavailable offline). Benches need a
//! trained checkpoint — run `make train` (or `drank train --model <m>`)
//! first; benches fail with a clear message otherwise.
//!
//! Env knobs (all optional):
//!   DRANK_FAST=1            cheaper grids (fewer ratios/items)
//!   DRANK_EVAL_BATCHES=n    PPL eval batches per domain (default 16)
//!   DRANK_TASK_ITEMS=n      items per zero-shot suite (default 60)
//!   DRANK_CALIB_BATCHES=n   calibration batches (default 12)

#![allow(dead_code)]

use drank::calib::{CalibOpts, CalibStats};
use drank::compress::{pipeline, CompressOpts, Method};
use drank::data::synlang::Domain;
use drank::data::DataBundle;
use drank::eval;
use drank::model::{ckpt_path, Weights};
use drank::report::Table;
use drank::runtime::Engine;

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Size the compression thread pool from `--threads` / `DRANK_THREADS`
/// (same resolution as the `drank` CLI). Call once at bench start.
pub fn init_threads() {
    let args = drank::util::cli::Args::from_env();
    drank::util::parallel::set_threads(args.threads_or_default());
}

pub fn fast() -> bool {
    std::env::var("DRANK_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn eval_batches() -> usize {
    env_usize("DRANK_EVAL_BATCHES", 16)
}

pub fn task_items() -> usize {
    env_usize("DRANK_TASK_ITEMS", 60)
}

pub fn calib_batches() -> usize {
    env_usize("DRANK_CALIB_BATCHES", 12)
}

pub struct Bench {
    pub engine: Engine,
    pub weights: Weights,
    pub data: DataBundle,
}

/// Load everything a bench needs for a logical model.
pub fn setup(model: &str) -> Bench {
    init_threads();
    let engine = Engine::open("artifacts").expect("run `make artifacts` first");
    let (weights, step) = Weights::load(&ckpt_path(model)).unwrap_or_else(|_| {
        panic!("no checkpoint for '{model}' — run `./target/release/drank train --model {model}` first")
    });
    eprintln!("[bench] {model}: checkpoint at step {step}");
    let data = DataBundle::build_cached(weights.config.vocab, 1234, 1.0);
    Bench { engine, weights, data }
}

impl Bench {
    pub fn calib_opts(&self, domain: Domain, fisher: bool) -> CalibOpts {
        CalibOpts { domain, batches: calib_batches(), seed: 13, fisher }
    }

    /// Calibrate once (optionally with Fisher rows for FWSVD).
    pub fn calibrate(&self, domain: Domain, fisher: bool) -> CalibStats {
        drank::calib::run(&self.engine, &self.weights, &self.data, &self.calib_opts(domain, fisher))
            .expect("calibration")
    }

    /// Compress with pre-computed stats (no compensation path).
    pub fn compress(
        &self,
        stats: &CalibStats,
        opts: &CompressOpts,
    ) -> drank::model::lowrank::CompressedModel {
        // compensation needs the engine+data; route through the pipeline
        if opts.compensate {
            let copts = self.calib_opts(Domain::Wiki2s, opts.method == Method::Fwsvd);
            let (m, _) = pipeline::compress_model(&self.engine, &self.weights, &self.data, &copts, opts)
                .expect("compress");
            m
        } else {
            let (m, _) =
                drank::compress::methods::compress(&self.weights, stats, opts).expect("compress");
            m
        }
    }

    /// PPL of a compressed model on a domain's test stream.
    pub fn ppl(&self, model: &drank::model::lowrank::CompressedModel, domain: Domain) -> f64 {
        eval::ppl_compressed(&self.engine, model, &self.data.domain(domain).test, eval_batches())
            .expect("ppl")
    }

    pub fn ppl_dense(&self, weights: &Weights, domain: Domain) -> f64 {
        eval::ppl_dense(&self.engine, weights, &self.data.domain(domain).test, eval_batches())
            .expect("ppl")
    }

    /// Zero-shot accuracies + average for (reconstructed) dense weights.
    pub fn zero_shot(&self, weights: &Weights) -> (Vec<(drank::data::tasks::Suite, f64)>, f64) {
        eval::tasks::run_all_suites(
            &self.engine,
            weights,
            &self.data.tokenizer,
            &self.data.lexicon,
            task_items(),
            17,
        )
        .expect("zero-shot")
    }
}

/// Print + persist a finished table.
pub fn emit(table: &Table, name: &str) {
    print!("{}", table.markdown());
    table.save_json(name).expect("save report");
    eprintln!("[bench] wrote runs/reports/{name}.json");
}

/// The standard method lineup in paper order.
pub fn all_methods() -> Vec<Method> {
    vec![
        Method::PlainSvd,
        Method::Fwsvd,
        Method::Asvd,
        Method::SvdLlm,
        Method::BasisSharing,
        Method::DRank,
    ]
}

/// Default compression options for a method at (ratio, n).
pub fn opts(method: Method, ratio: f64, n: usize) -> CompressOpts {
    CompressOpts { method, ratio, group_layers: n, ..Default::default() }
}
