//! Figure 4: serving throughput (tokens/sec) of the dense model vs
//! compressed models at ratios 20–50%, through the coordinator over
//! runtime-compiled factored graphs.
//!
//! Expected shape: every compressed model >= dense; throughput increases
//! with the compression ratio; D-Rank >= Basis Sharing (its allocations
//! skew rank toward cheap, high-value groups).

#[path = "common/mod.rs"]
mod common;

use drank::compress::Method;
use drank::coordinator::{Server, ServerOpts};
use drank::data::synlang::Domain;
use drank::model::lowrank::CompressedModel;
use drank::report::Table;
use drank::util::rng::Rng;

fn serve(model: CompressedModel, stream: &[u32], requests: usize) -> drank::coordinator::Metrics {
    let cfg = model.config();
    // serve with a larger batch than the eval artifacts use: the factored
    // matmuls only beat dense when the GEMMs are compute-bound, which at
    // tinylm widths needs more rows (paper-scale models are always there)
    let batch = common::env_usize("DRANK_SERVE_BATCH", 32);
    let server = Server::spawn(
        move || {
            let rt = drank::runtime::Runtime::cpu()?;
            drank::graph::compile_forward(&rt, &model, batch, cfg.seq)
        },
        ServerOpts::default(),
    );
    let clients = 8;
    let mut handles = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let stream = stream.to_vec();
        let seq = cfg.seq;
        let per = requests / clients;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64);
            for _ in 0..per {
                let start = rng.below(stream.len() - seq);
                client.score(stream[start..start + seq].to_vec()).expect("score");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown().expect("shutdown")
}

fn main() {
    let b = common::setup(&std::env::var("DRANK_SERVE_MODEL").unwrap_or_else(|_| "l".into()));
    let stats = b.calibrate(Domain::Wiki2s, false);
    let stream = b.data.domain(Domain::Wiki2s).test.clone();
    let requests = common::env_usize("DRANK_SERVE_REQUESTS", 160);
    let ratios: Vec<f64> = if common::fast() { vec![0.2, 0.5] } else { vec![0.2, 0.3, 0.4, 0.5] };

    let mut t = Table::new(
        &format!("Figure 4: serving throughput ({})", b.weights.config.name),
        &["Model", "tokens/s", "p50 ms", "p99 ms", "speedup vs dense"],
    );

    let dense = CompressedModel::dense_passthrough(b.weights.clone());
    let m0 = serve(dense, &stream, requests);
    let base = m0.throughput_tps();
    t.row(vec![
        "Dense".into(),
        format!("{:.0}", base),
        format!("{:.1}", m0.p50_ms()),
        format!("{:.1}", m0.p99_ms()),
        "1.00".into(),
    ]);
    eprintln!("dense: {base:.0} tok/s");

    for method in [Method::SvdLlm, Method::BasisSharing, Method::DRank] {
        for &ratio in &ratios {
            let model = b.compress(&stats, &common::opts(method, ratio, 2));
            let m = serve(model, &stream, requests);
            t.row(vec![
                format!("{} {:.0}%", method.name(), ratio * 100.0),
                format!("{:.0}", m.throughput_tps()),
                format!("{:.1}", m.p50_ms()),
                format!("{:.1}", m.p99_ms()),
                format!("{:.2}", m.throughput_tps() / base),
            ]);
            eprint!(".");
        }
        eprintln!(" {} done", method.name());
    }
    common::emit(&t, "fig4_throughput");
}
