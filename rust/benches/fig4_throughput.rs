//! Figure 4: serving throughput (tokens/sec) of the dense model vs
//! compressed models at ratios 20–50%, through the coordinator over
//! runtime-compiled factored graphs — plus a worker-count scaling curve
//! over the pure-Rust reference backend, a factored-vs-dense crossover
//! curve (Figure 4c) over the same backend, and a KV-cached generation
//! throughput curve (Figure 4d) through the coordinator's `Generate`
//! endpoint, dense and factored.
//!
//! Expected shape: every compressed model >= dense; throughput increases
//! with the compression ratio; D-Rank >= Basis Sharing (its allocations
//! skew rank toward cheap, high-value groups). On the scaling curve,
//! aggregate throughput rises with the worker count until the machine's
//! cores saturate. On the crossover curve, factored serving (two skinny
//! GEMMs, no weight rematerialization) must match or beat the
//! dense-reconstructed path once the ratio reaches 20% — the rank cut
//! makes (x·B)·C strictly less work than x·W.

#[path = "common/mod.rs"]
mod common;

use drank::compress::Method;
use drank::coordinator::{spawn_model_server, Server, ServerOpts};
use drank::data::synlang::Domain;
use drank::model::lowrank::CompressedModel;
use drank::report::Table;
use drank::util::rng::Rng;

fn drive(
    server: Server,
    stream: &[u32],
    seq: usize,
    requests: usize,
) -> drank::coordinator::Metrics {
    let clients = 8;
    let mut handles = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let stream = stream.to_vec();
        let per = requests / clients;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64);
            for _ in 0..per {
                let start = rng.below(stream.len() - seq);
                client.score(stream[start..start + seq].to_vec()).expect("score");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown().expect("shutdown")
}

fn serve(
    model: CompressedModel,
    stream: &[u32],
    requests: usize,
    backend: &str,
    workers: usize,
) -> drank::coordinator::Metrics {
    let cfg = model.config();
    // serve with a larger batch than the eval artifacts use: the factored
    // matmuls only beat dense when the GEMMs are compute-bound, which at
    // tinylm widths needs more rows (paper-scale models are always there)
    let batch = common::env_usize("DRANK_SERVE_BATCH", 32);
    let server = spawn_model_server(
        model,
        batch,
        cfg.seq,
        backend,
        ServerOpts { workers, ..Default::default() },
    )
    .expect("spawn");
    drive(server, stream, cfg.seq, requests)
}

fn main() {
    let b = common::setup(&std::env::var("DRANK_SERVE_MODEL").unwrap_or_else(|_| "l".into()));
    let stats = b.calibrate(Domain::Wiki2s, false);
    let stream = b.data.domain(Domain::Wiki2s).test.clone();
    let requests = common::env_usize("DRANK_SERVE_REQUESTS", 160);
    let ratios: Vec<f64> = if common::fast() { vec![0.2, 0.5] } else { vec![0.2, 0.3, 0.4, 0.5] };

    let mut t = Table::new(
        &format!("Figure 4: serving throughput ({})", b.weights.config.name),
        &["Model", "tokens/s", "p50 ms", "p99 ms", "speedup vs dense"],
    );

    let dense = CompressedModel::dense_passthrough(b.weights.clone());
    let m0 = serve(dense, &stream, requests, "xla", 1);
    let base = m0.throughput_tps();
    t.row(vec![
        "Dense".into(),
        format!("{:.0}", base),
        format!("{:.1}", m0.p50_ms()),
        format!("{:.1}", m0.p99_ms()),
        "1.00".into(),
    ]);
    eprintln!("dense: {base:.0} tok/s");

    for method in [Method::SvdLlm, Method::BasisSharing, Method::DRank] {
        for &ratio in &ratios {
            let model = b.compress(&stats, &common::opts(method, ratio, 2));
            let m = serve(model, &stream, requests, "xla", 1);
            t.row(vec![
                format!("{} {:.0}%", method.name(), ratio * 100.0),
                format!("{:.0}", m.throughput_tps()),
                format!("{:.1}", m.p50_ms()),
                format!("{:.1}", m.p99_ms()),
                format!("{:.2}", m.throughput_tps() / base),
            ]);
            eprint!(".");
        }
        eprintln!(" {} done", method.name());
    }
    common::emit(&t, "fig4_throughput");

    // ---- worker-count scaling over the reference backend -----------------
    // The acceptance bar: 2+ workers must beat the 1-worker baseline on the
    // same workload (each worker owns a full backend instance, so the
    // aggregate scales with cores).
    let worker_counts: Vec<usize> = if common::fast() { vec![1, 2] } else { vec![1, 2, 4] };
    let scale_requests = common::env_usize("DRANK_SCALE_REQUESTS", 64);
    let mut ts = Table::new(
        "Figure 4b: worker scaling (reference backend, dense weights)",
        &["Workers", "tokens/s", "speedup vs 1 worker", "occupancy", "utilization"],
    );
    let mut base_ref = 0.0;
    for &wk in &worker_counts {
        let dense = CompressedModel::dense_passthrough(b.weights.clone());
        let m = serve(dense, &stream, scale_requests, "ref", wk);
        let tput = m.throughput_tps();
        if base_ref == 0.0 {
            base_ref = tput;
        }
        ts.row(vec![
            format!("{wk}"),
            format!("{tput:.0}"),
            format!("{:.2}", tput / base_ref),
            format!("{:.2}", m.mean_batch_occupancy()),
            format!("{:.2}", m.utilization()),
        ]);
        eprintln!("ref backend, {wk} worker(s): {tput:.0} tok/s");
    }
    common::emit(&ts, "fig4_throughput_scaling");

    // ---- factored vs dense-reconstructed serving (reference backend) ------
    // The same compressed model served two ways: on its factors directly
    // (`RefBackend`'s factored mode) and as a dense passthrough of its
    // `to_dense()` reconstruction. Acceptance bar: factored >= dense at
    // every ratio >= 0.2 — the factored projections do strictly less work.
    let cross_requests = common::env_usize("DRANK_CROSS_REQUESTS", 64);
    let mut tc = Table::new(
        "Figure 4c: factored vs dense-reconstructed serving (reference backend)",
        &["Ratio", "factored tok/s", "dense tok/s", "factored/dense"],
    );
    for &ratio in &ratios {
        let model = b.compress(&stats, &common::opts(Method::DRank, ratio, 2));
        let reconstructed = CompressedModel::dense_passthrough(model.to_dense());
        let mf = serve(model, &stream, cross_requests, "ref", 1);
        let md = serve(reconstructed, &stream, cross_requests, "ref", 1);
        let (tf, td) = (mf.throughput_tps(), md.throughput_tps());
        tc.row(vec![
            format!("{:.0}%", ratio * 100.0),
            format!("{tf:.0}"),
            format!("{td:.0}"),
            format!("{:.2}", tf / td),
        ]);
        eprintln!("ref backend, ratio {ratio:.1}: factored {tf:.0} vs dense {td:.0} tok/s");
        assert!(
            tf >= td * 0.95,
            "factored serving ({tf:.0} tok/s) fell behind dense reconstruction \
             ({td:.0} tok/s) at ratio {ratio} — the low-rank path should do less work"
        );
    }
    common::emit(&tc, "fig4_throughput_factored");

    // ---- generation curve (reference backend) ----------------------------
    // tokens/sec of the KV-cached `Generate` endpoint as the decode length
    // grows. Per-token cost rises with the live prefix (cached attention is
    // O(prefix)), so decode tok/s decays gently with length; the factored
    // model's single-token projections are two skinny vec×mats, never a
    // reconstructed dense matrix.
    let gen_requests = common::env_usize("DRANK_GEN_REQUESTS", 16);
    let mut tg = Table::new(
        "Figure 4d: generation throughput (reference backend)",
        &["Model", "new tokens", "decode tok/s", "p50 ms"],
    );
    let cfg = b.weights.config;
    let prompt_len = (cfg.seq / 4).max(1);
    let news: Vec<usize> = if common::fast() {
        vec![cfg.seq / 8, cfg.seq / 2]
    } else {
        vec![cfg.seq / 8, cfg.seq / 4, cfg.seq / 2]
    };
    let gen_models: Vec<(String, CompressedModel)> = vec![
        ("dense".into(), CompressedModel::dense_passthrough(b.weights.clone())),
        ("drank 30%".into(), b.compress(&stats, &common::opts(Method::DRank, 0.3, 2))),
    ];
    for (name, model) in &gen_models {
        for &max_new in &news {
            let server = spawn_model_server(
                model.clone(),
                cfg.batch,
                cfg.seq,
                "ref",
                ServerOpts { workers: 1, ..Default::default() },
            )
            .expect("spawn");
            let clients = 4usize;
            let mut handles = Vec::new();
            for c in 0..clients {
                let client = server.client();
                let stream = stream.clone();
                let per = gen_requests / clients;
                handles.push(std::thread::spawn(move || {
                    let mut rng = Rng::new(200 + c as u64);
                    for _ in 0..per {
                        let start = rng.below(stream.len() - prompt_len);
                        let resp = client
                            .generate(stream[start..start + prompt_len].to_vec(), max_new)
                            .expect("generate");
                        assert_eq!(resp.tokens.len(), max_new);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            let m = server.shutdown().expect("shutdown");
            tg.row(vec![
                name.clone(),
                format!("{max_new}"),
                format!("{:.0}", m.decode_tps()),
                format!("{:.1}", m.p50_ms()),
            ]);
            eprintln!(
                "generate {name}, {max_new} new tokens: {:.0} decode tok/s",
                m.decode_tps()
            );
        }
    }
    common::emit(&tg, "fig4_throughput_generation");
}
