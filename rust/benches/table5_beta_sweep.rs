//! Table 5: β-rebalance sweep — PPL for β ∈ {0.2..0.45} × grouped layers
//! n ∈ {2,3,4} × ratios 20–50%, vs the Basis Sharing baseline row.
//!
//! Expected shape: a moderate β (≈0.3–0.4) beats both β=0 and large β,
//! and every D-Rank cell beats the Basis Sharing cell at the same (n, ratio).

#[path = "common/mod.rs"]
mod common;

use drank::compress::Method;
use drank::data::synlang::Domain;
use drank::report::{fmt_ppl, Table};

fn main() {
    let b = common::setup("m");
    let stats = b.calibrate(Domain::Wiki2s, false);

    let ratios: Vec<f64> = if common::fast() { vec![0.2, 0.4] } else { vec![0.2, 0.3, 0.4, 0.5] };
    let ns: Vec<usize> = if common::fast() { vec![2] } else { vec![2, 3, 4] };
    let betas: Vec<f64> = if common::fast() {
        vec![0.2, 0.3, 0.4]
    } else {
        vec![0.2, 0.25, 0.3, 0.35, 0.4, 0.45]
    };

    let mut header = vec!["beta \\ (ratio, n)".to_string()];
    for &r in &ratios {
        for &n in &ns {
            header.push(format!("{:.0}% n={n}", r * 100.0));
        }
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 5: beta sweep (m, wiki2s)", &header_refs);

    // Basis Sharing baseline row
    let mut cells = vec!["Basis Sharing".to_string()];
    for &ratio in &ratios {
        for &n in &ns {
            let model = b.compress(&stats, &common::opts(Method::BasisSharing, ratio, n));
            cells.push(fmt_ppl(b.ppl(&model, Domain::Wiki2s)));
            eprint!(".");
        }
    }
    t.row(cells);

    for &beta in &betas {
        let mut cells = vec![format!("{beta}")];
        for &ratio in &ratios {
            for &n in &ns {
                let mut o = common::opts(Method::DRank, ratio, n);
                o.beta = beta;
                let model = b.compress(&stats, &o);
                cells.push(fmt_ppl(b.ppl(&model, Domain::Wiki2s)));
                eprint!(".");
            }
        }
        t.row(cells);
        eprintln!(" beta {beta} done");
    }
    common::emit(&t, "table5_beta_sweep");
}
