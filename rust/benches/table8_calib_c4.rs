//! Table 8: calibration-transfer — calibrate on C4 (c4s), evaluate PPL on
//! both C4 and WikiText-2, grouped layers n ∈ {2..5}, at 20% compression.
//!
//! Expected shape: D-Rank < Basis Sharing < SVD-LLM on both the calibration
//! domain and the out-of-distribution domain.

#[path = "common/mod.rs"]
mod common;

use drank::compress::Method;
use drank::data::synlang::Domain;
use drank::report::{fmt_ppl, Table};

fn main() {
    let b = common::setup("m");
    let stats = b.calibrate(Domain::C4s, false);

    let mut t = Table::new(
        "Table 8: calibration on c4s @ 20%",
        &["Method", "Grouped layers", "c4s PPL", "wiki2s PPL"],
    );
    {
        let model = b.compress(&stats, &common::opts(Method::SvdLlm, 0.2, 1));
        t.row(vec![
            "SVD-LLM".into(),
            "-".into(),
            fmt_ppl(b.ppl(&model, Domain::C4s)),
            fmt_ppl(b.ppl(&model, Domain::Wiki2s)),
        ]);
    }
    let ns: Vec<usize> = if common::fast() { vec![2, 4] } else { vec![2, 3, 4, 5] };
    for method in [Method::BasisSharing, Method::DRank] {
        for &n in &ns {
            let model = b.compress(&stats, &common::opts(method, 0.2, n));
            t.row(vec![
                method.name().into(),
                n.to_string(),
                fmt_ppl(b.ppl(&model, Domain::C4s)),
                fmt_ppl(b.ppl(&model, Domain::Wiki2s)),
            ]);
            eprint!(".");
        }
    }
    eprintln!();
    common::emit(&t, "table8_calib_c4");
}
