//! Figure 5 (App. B.2): robustness to the calibration-data seed —
//! PPL at 20% for seeds {13, 42, 512, 1024}, three methods.
//!
//! Expected shape: all methods fluctuate mildly with the seed; D-Rank stays
//! lowest at every seed.

#[path = "common/mod.rs"]
mod common;

use drank::calib::CalibOpts;
use drank::compress::Method;
use drank::data::synlang::Domain;
use drank::report::{fmt_ppl, Table};

fn main() {
    let b = common::setup("m");
    let seeds: Vec<u64> = if common::fast() { vec![13, 512] } else { vec![13, 42, 512, 1024] };

    let mut header = vec!["Method".to_string()];
    header.extend(seeds.iter().map(|s| format!("seed {s}")));
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Figure 5: PPL @ 20% vs calibration seed (m, wiki2s)", &hrefs);

    for method in [Method::SvdLlm, Method::BasisSharing, Method::DRank] {
        let mut cells = vec![method.name().to_string()];
        for &seed in &seeds {
            let copts = CalibOpts {
                domain: Domain::Wiki2s,
                batches: common::calib_batches(),
                seed,
                fisher: false,
            };
            let stats =
                drank::calib::run(&b.engine, &b.weights, &b.data, &copts).expect("calib");
            let model = b.compress(&stats, &common::opts(method, 0.2, 2));
            cells.push(fmt_ppl(b.ppl(&model, Domain::Wiki2s)));
            eprint!(".");
        }
        t.row(cells);
        eprintln!(" {} done", method.name());
    }
    common::emit(&t, "fig5_seeds");
}
