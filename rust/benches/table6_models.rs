//! Table 6: PPL of different LLMs at 20% compression on wiki2s —
//! LLaMA-7B / LLaMA-2-7B / Mistral-7B analogs (m / m2 / mist).

#[path = "common/mod.rs"]
mod common;

use drank::compress::Method;
use drank::data::synlang::Domain;
use drank::report::{fmt_ppl, Table};

fn main() {
    let models = ["m", "m2", "mist"];
    let mut rows: Vec<Vec<String>> = common::all_methods()
        .iter()
        .map(|m| vec![m.name().to_string()])
        .collect();
    let mut orig = vec!["Original".to_string()];

    for name in models {
        let b = common::setup(name);
        let stats = b.calibrate(Domain::Wiki2s, true);
        orig.push(fmt_ppl(b.ppl_dense(&b.weights, Domain::Wiki2s)));
        for (mi, method) in common::all_methods().into_iter().enumerate() {
            // mist is GQA: D-Rank applies its n=1 policy automatically
            let model = b.compress(&stats, &common::opts(method, 0.2, 2));
            rows[mi].push(fmt_ppl(b.ppl(&model, Domain::Wiki2s)));
            eprint!(".");
        }
        eprintln!(" {name} done");
    }

    let mut t = Table::new(
        "Table 6: PPL of different LLMs @ 20% (wiki2s)",
        &["Method", "llama-7b (m)", "llama-2-7b (m2)", "mistral-7b (mist)"],
    );
    t.row(orig);
    for r in rows {
        t.row(r);
    }
    common::emit(&t, "table6_models");
}
