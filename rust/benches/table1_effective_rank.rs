//! Table 1 + Figure 2: effective rank of grouped W^V, W^K, W^Q matrices
//! per depth (paper: LLaMA-7B on WikiText-2, two layers per group).
//!
//! Expected shape: R_eff(V) >> R_eff(K), R_eff(Q); mid-depth groups richer
//! than the first group (the paper's U-shaped depth profile).

#[path = "common/mod.rs"]
mod common;

use drank::compress::methods::effective_ranks_table;
use drank::data::synlang::Domain;
use drank::report::Table;

fn main() {
    let b = common::setup("m");
    let stats = b.calibrate(Domain::Wiki2s, false);

    let n = 2;
    let rv = effective_ranks_table(&b.weights, &stats, "wv", n);
    let rk = effective_ranks_table(&b.weights, &stats, "wk", n);
    let rq = effective_ranks_table(&b.weights, &stats, "wq", n);

    let mut t = Table::new(
        "Table 1: effective rank of grouped V, K, Q (n=2, wiki2s calib)",
        &["Group Index", "V", "K", "Q"],
    );
    for g in 0..rv.len() {
        t.row(vec![
            (g + 1).to_string(),
            format!("{:.1}", rv[g]),
            format!("{:.1}", rk[g]),
            format!("{:.1}", rq[g]),
        ]);
    }
    common::emit(&t, "table1_effective_rank");

    // Figure 2 is the same data as a series; print it for the log
    println!("Figure 2 series (group -> V/K/Q):");
    for g in 0..rv.len() {
        println!("  g{}  V={:<8.1} K={:<8.1} Q={:<8.1}", g + 1, rv[g], rk[g], rq[g]);
    }
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    println!(
        "check: mean R_eff  V={:.1}  K={:.1}  Q={:.1}  (paper: V >> K,Q)",
        mean(&rv),
        mean(&rk),
        mean(&rq)
    );
}
