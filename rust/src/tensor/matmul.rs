//! Blocked matrix multiplication.
//!
//! The pipeline's own GEMM, used by whitening / SVD reconstruction AND the
//! pure-Rust serving forward (`model::fwd` batches every projection through
//! [`gemm_f32`]). i-k-j loop order with 64x64x64 blocking: the inner j-loop
//! is a contiguous FMA over both B and C rows, which the compiler
//! auto-vectorizes. Rows of C are computed in parallel bands
//! (`util::parallel::parallel_row_bands`); each output row's accumulation
//! order is fixed by the k/j blocking alone, so results are bit-identical
//! for any thread count. See EXPERIMENTS.md §Perf for measurements.

use super::{Mat32, MatF};
use crate::util::parallel::parallel_row_bands;

const BLOCK: usize = 64;

fn f64_band(a: &MatF, b: &MatF, row0: usize, cband: &mut [f64]) {
    let (k, n) = (a.cols, b.cols);
    let brows = cband.len() / n;
    for i0 in (0..brows).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(brows);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for j0 in (0..n).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(n);
                for i in i0..i1 {
                    let gi = row0 + i;
                    let arow = &a.data[gi * k..(gi + 1) * k];
                    let crow = &mut cband[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * n..(kk + 1) * n];
                        for j in j0..j1 {
                            crow[j] += av * brow[j];
                        }
                    }
                }
            }
        }
    }
}

/// C = A * B, f64.
pub fn matmul_f64(a: &MatF, b: &MatF) -> MatF {
    assert_eq!(a.cols, b.rows, "matmul dim mismatch");
    let (m, n) = (a.rows, b.cols);
    let mut c = MatF::zeros(m, n);
    parallel_row_bands(&mut c.data, m, n, |row0, band| f64_band(a, b, row0, band));
    c
}

fn f32_band(a: &[f32], k: usize, b: &[f32], n: usize, row0: usize, cband: &mut [f32]) {
    let brows = cband.len() / n;
    for i0 in (0..brows).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(brows);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let gi = row0 + i;
                let arow = &a[gi * k..(gi + 1) * k];
                let crow = &mut cband[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        }
    }
}

/// C = A * B over flat row-major slices: `a` is m×k, `b` is k×n, returns
/// the m×n product. This is the serving-forward workhorse — `model::fwd`
/// calls it with activation rows as A and a weight (or factor) slab as B,
/// avoiding any `Mat32` wrapping of model tensors. Same blocked kernel and
/// row-band parallelism as [`matmul_f32`], so output is bit-identical for
/// any thread count.
pub fn gemm_f32(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "gemm lhs shape mismatch");
    assert_eq!(b.len(), k * n, "gemm rhs shape mismatch");
    let mut c = vec![0.0f32; m * n];
    parallel_row_bands(&mut c, m, n, |row0, band| f32_band(a, k, b, n, row0, band));
    c
}

/// C = A * B, f32 (weight reconstruction W = B·C on the compression path).
pub fn matmul_f32(a: &Mat32, b: &Mat32) -> Mat32 {
    assert_eq!(a.cols, b.rows, "matmul dim mismatch");
    let (m, n) = (a.rows, b.cols);
    Mat32::from_vec(m, n, gemm_f32(&a.data, m, a.cols, &b.data, n))
}

/// y = x * A for a single row-vector x (serving-side helper).
pub fn vecmat_f32(x: &[f32], a: &Mat32) -> Vec<f32> {
    assert_eq!(x.len(), a.rows);
    let mut y = vec![0.0f32; a.cols];
    for (k, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let arow = a.row(k);
        for j in 0..a.cols {
            y[j] += xv * arow[j];
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::set_threads;
    use crate::util::rng::Rng;

    fn naive(a: &MatF, b: &MatF) -> MatF {
        let mut c = MatF::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    fn random(rng: &mut Rng, r: usize, c: usize) -> MatF {
        MatF::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn blocked_matches_naive_over_shapes() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (65, 130, 33), (128, 17, 96)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let got = matmul_f64(&a, &b);
            let want = naive(&a, &b);
            for (x, y) in got.data.iter().zip(&want.data) {
                assert!((x - y).abs() < 1e-9, "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn f32_matches_f64() {
        let mut rng = Rng::new(1);
        let a = random(&mut rng, 33, 47);
        let b = random(&mut rng, 47, 29);
        let got = matmul_f32(&a.to_f32(), &b.to_f32());
        let want = matmul_f64(&a, &b);
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((*x as f64 - y).abs() < 1e-3);
        }
    }

    #[test]
    fn parallel_bands_are_bit_identical() {
        let mut rng = Rng::new(7);
        let a = random(&mut rng, 97, 65);
        let b = random(&mut rng, 65, 51);
        // t_matmul contracts over rows: give it a same-row-count partner
        let c = random(&mut rng, 97, 51);
        let (a32, b32) = (a.to_f32(), b.to_f32());
        set_threads(1);
        let base64 = matmul_f64(&a, &b);
        let base32 = matmul_f32(&a32, &b32);
        let base_t = a.t_matmul(&c);
        for t in [2, 3, 4, 8] {
            set_threads(t);
            assert_eq!(matmul_f64(&a, &b).data, base64.data, "f64 @ {t} threads");
            assert_eq!(matmul_f32(&a32, &b32).data, base32.data, "f32 @ {t} threads");
            assert_eq!(a.t_matmul(&c).data, base_t.data, "t_matmul @ {t} threads");
        }
        set_threads(0);
    }

    #[test]
    fn gemm_slices_match_matmul_exactly() {
        let mut rng = Rng::new(5);
        let a = random(&mut rng, 37, 70).to_f32();
        let b = random(&mut rng, 70, 23).to_f32();
        let want = matmul_f32(&a, &b);
        let got = gemm_f32(&a.data, 37, 70, &b.data, 23);
        assert_eq!(got, want.data);
        set_threads(1);
        let t1 = gemm_f32(&a.data, 37, 70, &b.data, 23);
        set_threads(4);
        let t4 = gemm_f32(&a.data, 37, 70, &b.data, 23);
        set_threads(0);
        assert_eq!(t1, t4, "gemm_f32 not thread-invariant");
    }

    #[test]
    fn vecmat_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = random(&mut rng, 20, 30).to_f32();
        let x: Vec<f32> = (0..20).map(|_| rng.normal() as f32).collect();
        let xm = Mat32::from_vec(1, 20, x.clone());
        let want = matmul_f32(&xm, &a);
        let got = vecmat_f32(&x, &a);
        for (g, w) in got.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-5);
        }
    }
}
