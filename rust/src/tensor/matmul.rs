//! Blocked matrix multiplication.
//!
//! The pipeline's own GEMM, used by whitening / SVD reconstruction AND the
//! pure-Rust serving forward (`model::fwd` batches every projection through
//! [`gemm_f32`]). i-k-j loop order with 64x64x64 blocking: the inner j-loop
//! is a contiguous FMA over both B and C rows, which the compiler
//! auto-vectorizes. Rows of C are computed in parallel bands
//! (`util::parallel::parallel_row_bands`); each output row's accumulation
//! order is fixed by the k/j blocking alone, so results are bit-identical
//! for any thread count. See EXPERIMENTS.md §Perf for measurements.

use super::{Mat32, MatF};
use crate::util::parallel::parallel_row_bands;
use std::sync::atomic::{AtomicU64, Ordering};

const BLOCK: usize = 64;

/// Column width of a [`PackedMat`] panel. Equal to [`BLOCK`] so the packed
/// kernel's j-extent matches the unpacked kernel's cache blocking.
pub const PANEL: usize = 64;

fn f64_band(a: &MatF, b: &MatF, row0: usize, cband: &mut [f64]) {
    let (k, n) = (a.cols, b.cols);
    let brows = cband.len() / n;
    for i0 in (0..brows).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(brows);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for j0 in (0..n).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(n);
                for i in i0..i1 {
                    let gi = row0 + i;
                    let arow = &a.data[gi * k..(gi + 1) * k];
                    let crow = &mut cband[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        let av = arow[kk];
                        let brow = &b.data[kk * n..(kk + 1) * n];
                        for j in j0..j1 {
                            crow[j] += av * brow[j];
                        }
                    }
                }
            }
        }
    }
}

/// C = A * B, f64.
pub fn matmul_f64(a: &MatF, b: &MatF) -> MatF {
    assert_eq!(a.cols, b.rows, "matmul dim mismatch");
    let (m, n) = (a.rows, b.cols);
    let mut c = MatF::zeros(m, n);
    parallel_row_bands(&mut c.data, m, n, |row0, band| f64_band(a, b, row0, band));
    c
}

fn f32_band(a: &[f32], k: usize, b: &[f32], n: usize, row0: usize, cband: &mut [f32]) {
    let brows = cband.len() / n;
    for i0 in (0..brows).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(brows);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let gi = row0 + i;
                let arow = &a[gi * k..(gi + 1) * k];
                let crow = &mut cband[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        }
    }
}

/// C = A * B over flat row-major slices: `a` is m×k, `b` is k×n, returns
/// the m×n product. This is the serving-forward workhorse — `model::fwd`
/// calls it with activation rows as A and a weight (or factor) slab as B,
/// avoiding any `Mat32` wrapping of model tensors. Same blocked kernel and
/// row-band parallelism as [`matmul_f32`], so output is bit-identical for
/// any thread count.
pub fn gemm_f32(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "gemm lhs shape mismatch");
    assert_eq!(b.len(), k * n, "gemm rhs shape mismatch");
    let mut c = vec![0.0f32; m * n];
    parallel_row_bands(&mut c, m, n, |row0, band| f32_band(a, k, b, n, row0, band));
    c
}

/// C = A * B, f32 (weight reconstruction W = B·C on the compression path).
pub fn matmul_f32(a: &Mat32, b: &Mat32) -> Mat32 {
    assert_eq!(a.cols, b.rows, "matmul dim mismatch");
    let (m, n) = (a.rows, b.cols);
    Mat32::from_vec(m, n, gemm_f32(&a.data, m, a.cols, &b.data, n))
}

/// y = x * A for a single row-vector x (serving-side helper).
pub fn vecmat_f32(x: &[f32], a: &Mat32) -> Vec<f32> {
    assert_eq!(x.len(), a.rows);
    let mut y = vec![0.0f32; a.cols];
    for (k, &xv) in x.iter().enumerate() {
        let arow = a.row(k);
        for j in 0..a.cols {
            y[j] += xv * arow[j];
        }
    }
    y
}

// ---------------------------------------------------------------------------
// Packed-panel GEMM
// ---------------------------------------------------------------------------

static PACK_OPS: AtomicU64 = AtomicU64::new(0);

/// Process-global count of [`PackedMat::pack`] calls. Tests read deltas of
/// this around a region to assert that weight panels are packed exactly
/// once per `Linear` site (the pack-once contract of the serving cache).
pub fn pack_ops() -> u64 {
    PACK_OPS.load(Ordering::Relaxed)
}

/// A k×n RHS repacked into block-major column panels for the serving GEMM.
///
/// Layout: the columns are split into panels of width [`PANEL`]; panel `jp`
/// stores its k rows contiguously, each row padded to a fixed [`PANEL`]
/// stride (`data[jp·k·PANEL + kk·PANEL + j] = b[kk·n + jp·PANEL + j]`, zero
/// padding past the real width). The inner kernel then walks one panel with
/// unit stride instead of striding `n` floats between k-steps, so every
/// cache line it pulls is fully used. Weights are reused across every batch,
/// which is why `model::lowrank` packs them once per site and caches the
/// result (see `PackRegistry`).
pub struct PackedMat {
    /// k — contraction dimension (rows of the original B).
    pub rows: usize,
    /// n — output dimension (cols of the original B).
    pub cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for PackedMat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PackedMat({}x{})", self.rows, self.cols)
    }
}

impl PackedMat {
    /// Repack a row-major k×n slab into column panels. Counted in
    /// [`pack_ops`] so the pack-once caching contract is testable.
    pub fn pack(b: &[f32], k: usize, n: usize) -> Self {
        assert_eq!(b.len(), k * n, "pack shape mismatch");
        PACK_OPS.fetch_add(1, Ordering::Relaxed);
        let np = n.div_ceil(PANEL);
        let mut data = vec![0.0f32; np * k * PANEL];
        for jp in 0..np {
            let j0 = jp * PANEL;
            let w = PANEL.min(n - j0);
            let base = jp * k * PANEL;
            for kk in 0..k {
                data[base + kk * PANEL..base + kk * PANEL + w]
                    .copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
            }
        }
        PackedMat { rows: k, cols: n, data }
    }

    #[inline]
    fn panel(&self, jp: usize) -> &[f32] {
        &self.data[jp * self.rows * PANEL..(jp + 1) * self.rows * PANEL]
    }
}

/// Packed-kernel band: same i/k blocking as [`f32_band`], panels instead of
/// a j-block loop. For every output element the k-accumulation order is
/// ascending within each k-block and blocks run in ascending order — exactly
/// the order of the unpacked kernel — so packed and unpacked results are
/// **byte-identical**; the panel layout and the register accumulator change
/// only where operands are read from, never the FP op sequence.
fn f32_band_packed(a: &[f32], k: usize, bp: &PackedMat, row0: usize, cband: &mut [f32]) {
    let n = bp.cols;
    cband.fill(0.0);
    if n == 0 {
        return;
    }
    let brows = cband.len() / n;
    let np = n.div_ceil(PANEL);
    for i0 in (0..brows).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(brows);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for jp in 0..np {
                let j0 = jp * PANEL;
                let w = PANEL.min(n - j0);
                let panel = bp.panel(jp);
                for i in i0..i1 {
                    let gi = row0 + i;
                    let arow = &a[gi * k..(gi + 1) * k];
                    let crow = &mut cband[i * n + j0..i * n + j0 + w];
                    // Register-blocked accumulator: load the C row once per
                    // k-block instead of once per k-step. The running value
                    // and the order of adds into it are unchanged.
                    let mut acc = [0.0f32; PANEL];
                    acc[..w].copy_from_slice(crow);
                    for kk in k0..k1 {
                        let av = arow[kk];
                        let prow = &panel[kk * PANEL..kk * PANEL + w];
                        for (c, &pv) in acc[..w].iter_mut().zip(prow) {
                            *c += av * pv;
                        }
                    }
                    crow.copy_from_slice(&acc[..w]);
                }
            }
        }
    }
}

/// C = A * Bp with a pre-packed RHS; row-band parallel like [`gemm_f32`]
/// and byte-identical to it (see [`f32_band_packed`]).
pub fn gemm_f32_packed(a: &[f32], m: usize, k: usize, bp: &PackedMat) -> Vec<f32> {
    let mut c = vec![0.0f32; m * bp.cols];
    gemm_f32_packed_into(a, m, k, bp, &mut c);
    c
}

/// [`gemm_f32_packed`] into a caller-owned buffer (overwritten, may be
/// dirty) — the fused factored path reuses one scratch buffer per thread
/// instead of allocating the (x·B) intermediate on every call.
pub fn gemm_f32_packed_into(a: &[f32], m: usize, k: usize, bp: &PackedMat, c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "gemm lhs shape mismatch");
    assert_eq!(k, bp.rows, "gemm packed rhs shape mismatch");
    assert_eq!(c.len(), m * bp.cols, "gemm out shape mismatch");
    parallel_row_bands(c, m, bp.cols, |row0, band| f32_band_packed(a, k, bp, row0, band));
}

/// Serial (no-spawn) [`gemm_f32_packed_into`] for callers already inside a
/// parallel region — e.g. the fused lm_head/cross-entropy band loop, which
/// runs one packed GEMM per row chunk on its own band thread.
pub fn gemm_f32_packed_serial(a: &[f32], m: usize, k: usize, bp: &PackedMat, c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "gemm lhs shape mismatch");
    assert_eq!(k, bp.rows, "gemm packed rhs shape mismatch");
    assert_eq!(c.len(), m * bp.cols, "gemm out shape mismatch");
    f32_band_packed(a, k, bp, 0, c);
}

/// y = x · Bp for a single row-vector `x` against a pre-packed RHS — the
/// decode hot path, where every projection sees exactly one token. `y` is
/// overwritten (may be dirty). Each output element accumulates with plain
/// ascending k, which is exactly the per-element order of
/// [`gemm_f32_packed`] (its k-block loop only tiles the same ascending
/// walk), so the result is **byte-identical** to a 1-row packed GEMM —
/// prefill (batched GEMM) and decode (this kernel) agree bitwise on the
/// same inputs. Serial by design: one row is far too little work to
/// amortize a band spawn, and callers may already sit inside a parallel
/// region.
pub fn vecmat_f32_packed(x: &[f32], bp: &PackedMat, y: &mut [f32]) {
    assert_eq!(x.len(), bp.rows, "vecmat lhs shape mismatch");
    assert_eq!(y.len(), bp.cols, "vecmat out shape mismatch");
    let n = bp.cols;
    if n == 0 {
        return;
    }
    let np = n.div_ceil(PANEL);
    for jp in 0..np {
        let j0 = jp * PANEL;
        let w = PANEL.min(n - j0);
        let panel = bp.panel(jp);
        let mut acc = [0.0f32; PANEL];
        for (kk, &xv) in x.iter().enumerate() {
            let prow = &panel[kk * PANEL..kk * PANEL + w];
            for (c, &pv) in acc[..w].iter_mut().zip(prow) {
                *c += xv * pv;
            }
        }
        y[j0..j0 + w].copy_from_slice(&acc[..w]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::set_threads;
    use crate::util::rng::Rng;

    fn naive(a: &MatF, b: &MatF) -> MatF {
        let mut c = MatF::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    fn random(rng: &mut Rng, r: usize, c: usize) -> MatF {
        MatF::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn blocked_matches_naive_over_shapes() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (65, 130, 33), (128, 17, 96)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let got = matmul_f64(&a, &b);
            let want = naive(&a, &b);
            for (x, y) in got.data.iter().zip(&want.data) {
                assert!((x - y).abs() < 1e-9, "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn f32_matches_f64() {
        let mut rng = Rng::new(1);
        let a = random(&mut rng, 33, 47);
        let b = random(&mut rng, 47, 29);
        let got = matmul_f32(&a.to_f32(), &b.to_f32());
        let want = matmul_f64(&a, &b);
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((*x as f64 - y).abs() < 1e-3);
        }
    }

    #[test]
    fn parallel_bands_are_bit_identical() {
        let mut rng = Rng::new(7);
        let a = random(&mut rng, 97, 65);
        let b = random(&mut rng, 65, 51);
        // t_matmul contracts over rows: give it a same-row-count partner
        let c = random(&mut rng, 97, 51);
        let (a32, b32) = (a.to_f32(), b.to_f32());
        set_threads(1);
        let base64 = matmul_f64(&a, &b);
        let base32 = matmul_f32(&a32, &b32);
        let base_t = a.t_matmul(&c);
        for t in [2, 3, 4, 8] {
            set_threads(t);
            assert_eq!(matmul_f64(&a, &b).data, base64.data, "f64 @ {t} threads");
            assert_eq!(matmul_f32(&a32, &b32).data, base32.data, "f32 @ {t} threads");
            assert_eq!(a.t_matmul(&c).data, base_t.data, "t_matmul @ {t} threads");
        }
        set_threads(0);
    }

    #[test]
    fn gemm_slices_match_matmul_exactly() {
        let mut rng = Rng::new(5);
        let a = random(&mut rng, 37, 70).to_f32();
        let b = random(&mut rng, 70, 23).to_f32();
        let want = matmul_f32(&a, &b);
        let got = gemm_f32(&a.data, 37, 70, &b.data, 23);
        assert_eq!(got, want.data);
        set_threads(1);
        let t1 = gemm_f32(&a.data, 37, 70, &b.data, 23);
        set_threads(4);
        let t4 = gemm_f32(&a.data, 37, 70, &b.data, 23);
        set_threads(0);
        assert_eq!(t1, t4, "gemm_f32 not thread-invariant");
    }

    #[test]
    fn packed_gemm_is_byte_identical_to_unpacked_over_shapes() {
        let mut rng = Rng::new(11);
        // ragged in every dimension: partial panels, partial k/i blocks
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (65, 130, 33), (37, 70, 129), (16, 200, 64)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let want = gemm_f32(&a, m, k, &b, n);
            let bp = PackedMat::pack(&b, k, n);
            let got = gemm_f32_packed(&a, m, k, &bp);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got), bits(&want), "packed != unpacked at ({m},{k},{n})");
            // serial variant and dirty-buffer reuse give the same bytes
            let mut dirty = vec![f32::NAN; m * n];
            gemm_f32_packed_serial(&a, m, k, &bp, &mut dirty);
            assert_eq!(bits(&dirty), bits(&want), "serial packed at ({m},{k},{n})");
        }
    }

    #[test]
    fn packed_gemm_is_thread_invariant() {
        let mut rng = Rng::new(12);
        let (m, k, n) = (97, 65, 51);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let bp = PackedMat::pack(&b, k, n);
        set_threads(1);
        let base = gemm_f32_packed(&a, m, k, &bp);
        for t in [2, 3, 4, 8] {
            set_threads(t);
            let got = gemm_f32_packed(&a, m, k, &bp);
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                base.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "packed gemm @ {t} threads"
            );
        }
        set_threads(0);
    }

    #[test]
    fn pack_ops_counts_packs() {
        let before = pack_ops();
        let b: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let _p = PackedMat::pack(&b, 2, 3);
        let _q = PackedMat::pack(&b, 3, 2);
        assert!(pack_ops() >= before + 2);
    }

    #[test]
    fn packed_vecmat_is_byte_identical_to_one_row_packed_gemm() {
        let mut rng = Rng::new(13);
        // ragged panels and contraction lengths, plus the degenerate edges
        for &(k, n) in &[(1, 1), (5, 7), (64, 64), (64, 65), (130, 33), (70, 129), (200, 256)] {
            let x: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let bp = PackedMat::pack(&b, k, n);
            let want = gemm_f32_packed(&x, 1, k, &bp);
            let mut got = vec![f32::NAN; n]; // dirty buffer must be overwritten
            vecmat_f32_packed(&x, &bp, &mut got);
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got), bits(&want), "vecmat != 1-row gemm at ({k},{n})");
        }
    }

    #[test]
    fn vecmat_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = random(&mut rng, 20, 30).to_f32();
        let x: Vec<f32> = (0..20).map(|_| rng.normal() as f32).collect();
        let xm = Mat32::from_vec(1, 20, x.clone());
        let want = matmul_f32(&xm, &a);
        let got = vecmat_f32(&x, &a);
        for (g, w) in got.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-5);
        }
    }
}
