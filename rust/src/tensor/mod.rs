//! Dense row-major matrix/tensor substrate.
//!
//! The compression pipeline (whitening, SVD, allocation) runs on `Mat<f64>`
//! for precision — the paper keeps the whitening matrix S in FP64 — while
//! model weights travel as `Mat<f32>`/flat `Vec<f32>`. Only what the
//! pipeline needs is implemented; heavy inference math lives in XLA.

pub mod matmul;

use std::fmt;

/// Row-major 2-D matrix.
#[derive(Clone, PartialEq)]
pub struct Mat<T> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<T>,
}

pub type MatF = Mat<f64>;
pub type Mat32 = Mat<f32>;

impl<T: Copy + Default> Mat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![T::default(); rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> T {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut T {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Self {
        let mut out = Self::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Horizontal concatenation [A | B | ...] (Basis-Sharing grouping).
    pub fn hcat(mats: &[&Mat<T>]) -> Self {
        assert!(!mats.is_empty());
        let rows = mats[0].rows;
        assert!(mats.iter().all(|m| m.rows == rows), "row mismatch in hcat");
        let cols: usize = mats.iter().map(|m| m.cols).sum();
        let mut out = Self::zeros(rows, cols);
        for r in 0..rows {
            let mut off = 0;
            for m in mats {
                out.row_mut(r)[off..off + m.cols].copy_from_slice(m.row(r));
                off += m.cols;
            }
        }
        out
    }

    /// Split into equal column blocks (inverse of hcat for equal widths).
    pub fn hsplit(&self, n: usize) -> Vec<Mat<T>> {
        assert_eq!(self.cols % n, 0, "cols not divisible");
        let w = self.cols / n;
        (0..n)
            .map(|i| {
                let mut b = Mat::zeros(self.rows, w);
                for r in 0..self.rows {
                    b.row_mut(r).copy_from_slice(&self.row(r)[i * w..(i + 1) * w]);
                }
                b
            })
            .collect()
    }
}

impl MatF {
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    pub fn from_f32(m: &Mat32) -> Self {
        Self {
            rows: m.rows,
            cols: m.cols,
            data: m.data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_f32(&self) -> Mat32 {
        Mat32 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| x as f32).collect(),
        }
    }

    /// C = A * B (blocked f64 matmul; see tensor::matmul).
    pub fn matmul(&self, b: &MatF) -> MatF {
        matmul::matmul_f64(self, b)
    }

    /// C = A^T * B without materializing A^T.
    ///
    /// Output rows are computed in parallel bands; each (i, j) cell still
    /// accumulates over k in ascending order, so the result is bit-identical
    /// for any thread count.
    pub fn t_matmul(&self, b: &MatF) -> MatF {
        assert_eq!(self.rows, b.rows);
        let (orows, ocols) = (self.cols, b.cols);
        let mut out = MatF::zeros(orows, ocols);
        crate::util::parallel::parallel_row_bands(&mut out.data, orows, ocols, |i0, band| {
            let brows = band.len() / ocols;
            for k in 0..self.rows {
                let arow = self.row(k);
                let brow = b.row(k);
                for i in 0..brows {
                    let a = arow[i0 + i];
                    let orow = &mut band[i * ocols..(i + 1) * ocols];
                    for j in 0..ocols {
                        orow[j] += a * brow[j];
                    }
                }
            }
        });
        out
    }

    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add_assign(&mut self, other: &MatF) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &MatF) -> MatF {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        MatF {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Scale row r by s (diagonal left-multiplication building block).
    pub fn scale_row(&mut self, r: usize, s: f64) {
        for x in self.row_mut(r) {
            *x *= s;
        }
    }
}

impl<T: fmt::Debug + Copy + Default> fmt::Debug for Mat<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat[{}x{}]", self.rows, self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, v: &[f64]) -> MatF {
        MatF::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn transpose_roundtrip() {
        let a = mat(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(2, 1), 6.0);
    }

    #[test]
    fn hcat_hsplit_roundtrip() {
        let a = mat(2, 2, &[1., 2., 3., 4.]);
        let b = mat(2, 2, &[5., 6., 7., 8.]);
        let cat = MatF::hcat(&[&a, &b]);
        assert_eq!(cat.cols, 4);
        assert_eq!(cat.row(0), &[1., 2., 5., 6.]);
        let parts = cat.hsplit(2);
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn t_matmul_matches_explicit() {
        let a = mat(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let b = mat(3, 2, &[1., 0., 0., 1., 1., 1.]);
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_matmul() {
        let a = mat(2, 2, &[1., 2., 3., 4.]);
        let i = MatF::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn frob_norm() {
        let a = mat(1, 2, &[3., 4.]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-12);
    }
}
