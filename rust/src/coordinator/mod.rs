//! Serving coordinator: request router + dynamic batcher over a compiled
//! forward graph (the L3 runtime the paper's throughput numbers come from).
//!
//! Architecture (std threads + channels; tokio is unavailable offline):
//!
//! ```text
//!   clients ──score()──▶ bounded channel (backpressure)
//!                           │
//!                    batcher/worker thread
//!                    (owns the PJRT objects, which are !Send:
//!                     builds the graph, drains up to `batch`
//!                     requests per window, pads, executes)
//!                           │
//!   clients ◀──Response── per-request reply channels
//! ```
//!
//! Scoring requests return per-token NLL (the serving primitive behind
//! PPL evaluation, option scoring, and reranking workloads).

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::graph::CompiledForward;
use crate::util::percentile;

/// A scoring request: token ids (<= model seq len).
pub struct Request {
    pub tokens: Vec<u32>,
    pub reply: Sender<Response>,
    pub enqueued: Instant,
}

/// Per-request response.
#[derive(Clone, Debug)]
pub struct Response {
    /// per-token NLL over the request's own tokens (len = tokens-1)
    pub nll: Vec<f32>,
    pub latency_ms: f64,
}

/// Aggregate serving metrics.
#[derive(Default, Clone, Debug)]
pub struct Metrics {
    pub requests: usize,
    pub tokens: usize,
    pub batches: usize,
    pub latencies_ms: Vec<f64>,
    pub busy_secs: f64,
    pub wall_secs: f64,
}

impl Metrics {
    pub fn throughput_tps(&self) -> f64 {
        self.tokens as f64 / self.wall_secs.max(1e-9)
    }

    pub fn p50_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 99.0)
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        self.requests as f64 / self.batches.max(1) as f64
    }
}

/// Coordinator configuration.
pub struct ServerOpts {
    /// request queue bound (backpressure: senders block when full)
    pub queue: usize,
    /// how long the batcher waits to fill a batch before dispatching
    pub batch_window: Duration,
}

impl Default for ServerOpts {
    fn default() -> Self {
        Self { queue: 256, batch_window: Duration::from_millis(2) }
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Request>,
}

impl Client {
    /// Blocking score call.
    pub fn score(&self, tokens: Vec<u32>) -> Result<Response> {
        let (rtx, rrx) = std::sync::mpsc::channel();
        self.tx
            .send(Request { tokens, reply: rtx, enqueued: Instant::now() })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))
    }
}

/// A running scoring server.
pub struct Server {
    tx: Option<SyncSender<Request>>,
    worker: Option<JoinHandle<Result<()>>>,
    pub metrics: Arc<Mutex<Metrics>>,
}

impl Server {
    /// Spawn the worker. `make_forward` runs *inside* the worker thread
    /// because PJRT handles are not Send (same pattern as a GPU worker
    /// owning its CUDA context).
    pub fn spawn<F>(make_forward: F, opts: ServerOpts) -> Self
    where
        F: FnOnce() -> Result<CompiledForward> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(opts.queue);
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let m2 = metrics.clone();
        let worker = std::thread::spawn(move || worker_loop(make_forward, rx, opts, m2));
        Self { tx: Some(tx), worker: Some(worker), metrics }
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.as_ref().expect("server running").clone() }
    }

    /// Stop accepting requests and join the worker.
    pub fn shutdown(mut self) -> Result<Metrics> {
        drop(self.tx.take()); // closes the channel; worker drains + exits
        let res = self.worker.take().unwrap().join().expect("worker panicked");
        res?;
        let m = self.metrics.lock().unwrap().clone();
        Ok(m)
    }
}

fn worker_loop(
    make_forward: impl FnOnce() -> Result<CompiledForward>,
    rx: Receiver<Request>,
    opts: ServerOpts,
    metrics: Arc<Mutex<Metrics>>,
) -> Result<()> {
    let fwd = make_forward()?;
    let (bsz, seq) = (fwd.batch, fwd.seq);
    let wall = Instant::now();
    loop {
        // block for the first request of the batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all clients gone
        };
        let mut batch = vec![first];
        // fill the rest of the batch within the window
        let deadline = Instant::now() + opts.batch_window;
        while batch.len() < bsz {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // pad + execute
        let mut tokens = vec![0i32; bsz * seq];
        for (row, req) in batch.iter().enumerate() {
            for (i, &t) in req.tokens.iter().take(seq).enumerate() {
                tokens[row * seq + i] = t as i32;
            }
        }
        let busy = Instant::now();
        let nll = fwd.nll(&tokens)?;
        let busy_secs = busy.elapsed().as_secs_f64();

        let mut m = metrics.lock().unwrap();
        m.batches += 1;
        m.busy_secs += busy_secs;
        for (row, req) in batch.into_iter().enumerate() {
            let n = req.tokens.len().min(seq);
            let row_nll = nll[row * (seq - 1)..row * (seq - 1) + n.saturating_sub(1)].to_vec();
            let latency_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
            m.requests += 1;
            m.tokens += n;
            m.latencies_ms.push(latency_ms);
            let _ = req.reply.send(Response { nll: row_nll, latency_ms });
        }
        m.wall_secs = wall.elapsed().as_secs_f64();
    }
    let mut m = metrics.lock().unwrap();
    m.wall_secs = wall.elapsed().as_secs_f64();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_math() {
        let m = Metrics {
            requests: 10,
            tokens: 960,
            batches: 4,
            latencies_ms: vec![1.0, 2.0, 3.0, 4.0],
            busy_secs: 0.5,
            wall_secs: 2.0,
        };
        assert!((m.throughput_tps() - 480.0).abs() < 1e-9);
        assert_eq!(m.mean_batch_occupancy(), 2.5);
        assert!(m.p50_ms() >= 1.0 && m.p99_ms() <= 4.0);
    }
}
