//! Serving coordinator: request router + dynamic batcher over N worker
//! threads, each owning its own [`ScoreBackend`] (the L3 runtime the
//! paper's throughput numbers come from).
//!
//! Architecture (std threads + condvar queue; tokio is unavailable offline):
//!
//! ```text
//!   clients ──score()/try_score()──▶ SharedQueue (bounded, Mutex+Condvar)
//!                                        │  QueueFull / Timeout / TooLong
//!                                        │  rejected with typed errors
//!              ┌─────────────────────────┼─────────────────────────┐
//!        worker 0                   worker 1        ...       worker N-1
//!   (each thread builds its own backend via the factory — PJRT
//!    handles are !Send; drains up to `batch` length-bucketed
//!    requests per window, pads, executes, replies)
//!              └─────────────────────────┴─────────────────────────┘
//!   clients ◀──Result<Response, ScoreError>── per-request reply channels
//! ```
//!
//! The backend seam ([`ScoreBackend`]) is pluggable: production serves the
//! runtime-compiled XLA graph, while [`RefBackend`] (pure Rust, no
//! artifacts) backs the coordinator test suite and artifact-free serving.
//! Shutdown closes the queue and drains every in-flight request before the
//! workers exit; per-worker metrics, queue-depth samples, and padding
//! efficiency land in [`Metrics`].
//!
//! Two request kinds share the queue: `Score` (batched NLL over a fixed
//! window) and `Generate` (KV-cached prefill + decode, served through the
//! [`GenerateBackend`] seam). Batches are always kind-homogeneous;
//! generation batches are assembled under a token budget
//! (Σ prompt+max_new ≤ batch·seq) and bucketed by *total* length, so a
//! short prompt asking for many tokens rides with its true cost class.
//! Backends without a decode path reject `Generate` requests with the
//! typed [`ScoreError::NotGenerative`] instead of panicking a worker.

pub mod backend;

pub use backend::{GenerateBackend, RefBackend, ScoreBackend};

use std::collections::VecDeque;
use std::fmt;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::util::percentile;

/// What a queued request asks the backend to do with its tokens.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RequestKind {
    /// Score the tokens: per-position NLL over the request's own window.
    Score,
    /// Autoregressively extend the tokens (the prompt) by up to
    /// `max_new_tokens`, greedy at `temperature == 0.0`, seeded
    /// categorical sampling otherwise.
    Generate { max_new_tokens: usize, temperature: f64, seed: u64 },
}

/// A queued request: token ids plus what to do with them. A request's
/// *total* length (`tokens + max_new` for generation) must fit the
/// backend's seq capacity, or it is rejected with `TooLong`.
pub struct Request {
    pub tokens: Vec<u32>,
    pub kind: RequestKind,
    pub reply: Sender<ScoreResult>,
    pub enqueued: Instant,
}

impl Request {
    /// Token-slots this request will occupy when executed: its own length
    /// for scoring, prompt plus the full generation budget for generation
    /// (admission and bucketing must price the KV cache it will fill, not
    /// just the prompt).
    fn total_len(&self) -> usize {
        match self.kind {
            RequestKind::Score => self.tokens.len(),
            RequestKind::Generate { max_new_tokens, .. } => {
                self.tokens.len() + max_new_tokens
            }
        }
    }

    fn is_generate(&self) -> bool {
        matches!(self.kind, RequestKind::Generate { .. })
    }
}

/// Per-request response.
#[derive(Clone, Debug)]
pub struct Response {
    /// per-token NLL over the request's own tokens (len = tokens-1);
    /// empty for `Generate` responses
    pub nll: Vec<f32>,
    /// newly generated token ids (len <= max_new_tokens); empty for
    /// `Score` responses
    pub tokens: Vec<i32>,
    pub latency_ms: f64,
    /// which worker served the request
    pub worker: usize,
}

/// Typed rejection/failure reasons — explicit instead of unbounded blocking.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ScoreError {
    /// `try_score` found the bounded queue at capacity.
    QueueFull,
    /// The request spent longer than the configured deadline queued.
    Timeout,
    /// The request exceeds the backend's sequence capacity (no silent
    /// truncation: the old coordinator clipped with `take(seq)`).
    TooLong { len: usize, seq: usize },
    /// A token id is outside the backend's vocabulary — rejected per
    /// request instead of letting one malformed id poison a whole batch
    /// (or panic a worker).
    InvalidToken { id: u32, vocab: usize },
    /// A `Generate` request reached a backend with no decode path (the
    /// fixed-shape compiled graph) — rejected per request, typed, instead
    /// of panicking the worker that drew it.
    NotGenerative,
    /// The server stopped before (or while) handling the request.
    Shutdown,
    /// The backend failed to build or to execute.
    Backend(String),
}

impl fmt::Display for ScoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScoreError::QueueFull => write!(f, "request queue full"),
            ScoreError::Timeout => write!(f, "deadline exceeded while queued"),
            ScoreError::TooLong { len, seq } => {
                write!(f, "request of {len} tokens exceeds backend seq {seq}")
            }
            ScoreError::InvalidToken { id, vocab } => {
                write!(f, "token id {id} outside vocabulary of {vocab}")
            }
            ScoreError::NotGenerative => {
                write!(f, "backend has no generation path")
            }
            ScoreError::Shutdown => write!(f, "server stopped"),
            ScoreError::Backend(e) => write!(f, "backend error: {e}"),
        }
    }
}

impl std::error::Error for ScoreError {}

pub type ScoreResult = std::result::Result<Response, ScoreError>;

/// Per-worker slice of the aggregate metrics.
#[derive(Default, Clone, Debug)]
pub struct WorkerMetrics {
    pub requests: usize,
    pub tokens: usize,
    pub batches: usize,
    pub busy_secs: f64,
}

/// Aggregate serving metrics.
#[derive(Default, Clone, Debug)]
pub struct Metrics {
    pub requests: usize,
    pub tokens: usize,
    pub batches: usize,
    pub latencies_ms: Vec<f64>,
    pub busy_secs: f64,
    pub wall_secs: f64,
    /// token-slots the backends actually executed: `rows * used_seq` per
    /// batch for shape-flexible backends, the full `batch * seq` for
    /// fixed-shape compiled graphs
    pub padded_tokens: usize,
    /// running sum of queue depth sampled as each batch was assembled
    /// (O(1) memory for long-lived servers; mean via `mean_queue_depth`)
    pub queue_depth_sum: usize,
    /// number of queue-depth samples (== batches that recorded one)
    pub queue_depth_samples: usize,
    pub rejected_queue_full: usize,
    pub rejected_timeout: usize,
    pub rejected_too_long: usize,
    pub rejected_invalid_token: usize,
    pub rejected_not_generative: usize,
    /// tokens decoded by `Generate` requests (subset of `tokens`)
    pub generated_tokens: usize,
    pub per_worker: Vec<WorkerMetrics>,
}

impl Metrics {
    pub fn throughput_tps(&self) -> f64 {
        self.tokens as f64 / self.wall_secs.max(1e-9)
    }

    pub fn p50_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        percentile(&self.latencies_ms, 99.0)
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        self.requests as f64 / self.batches.max(1) as f64
    }

    /// Useful tokens / executed token-slots (1.0 = zero padding waste).
    pub fn padding_efficiency(&self) -> f64 {
        self.tokens as f64 / self.padded_tokens.max(1) as f64
    }

    pub fn mean_queue_depth(&self) -> f64 {
        if self.queue_depth_samples == 0 {
            return 0.0;
        }
        self.queue_depth_sum as f64 / self.queue_depth_samples as f64
    }

    /// Aggregate busy fraction across workers (1.0 = all workers saturated).
    pub fn utilization(&self) -> f64 {
        let n = self.per_worker.len().max(1) as f64;
        self.busy_secs / (self.wall_secs.max(1e-9) * n)
    }

    pub fn rejected(&self) -> usize {
        self.rejected_queue_full
            + self.rejected_timeout
            + self.rejected_too_long
            + self.rejected_invalid_token
            + self.rejected_not_generative
    }

    /// Decode throughput: generated tokens per busy second (generation is
    /// decode-bound, so busy time is the honest denominator for a mixed
    /// score/generate workload).
    pub fn decode_tps(&self) -> f64 {
        self.generated_tokens as f64 / self.busy_secs.max(1e-9)
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServerOpts {
    /// request queue bound (scores block for space; try_score rejects)
    pub queue: usize,
    /// how long a worker waits to fill a batch before dispatching
    pub batch_window: Duration,
    /// worker threads, each with its own backend instance
    pub workers: usize,
    /// per-request queueing deadline; exceeded requests get `Timeout`
    pub deadline: Option<Duration>,
    /// assemble batches from same-length-bucket requests, so the executed
    /// window (the longest request in the batch) stays small — this is
    /// what lets short requests run at short-sequence cost. Only applied
    /// on shape-flexible backends; fixed-shape compiled graphs always run
    /// the full window, where bucketing would just fragment batches
    pub bucket_by_length: bool,
    /// size the process-wide `util/parallel.rs` pool the backends compute
    /// on — the same knob as `--threads` on the compression side, so one
    /// flag sizes both halves of the system. 0 leaves the current setting
    /// untouched (CLI default: whatever `main` already configured).
    pub threads: usize,
}

impl Default for ServerOpts {
    fn default() -> Self {
        Self {
            queue: 256,
            batch_window: Duration::from_millis(2),
            workers: 1,
            deadline: None,
            bucket_by_length: true,
            threads: 0,
        }
    }
}

/// Length bucket: requests whose lengths share a padded power-of-two
/// bucket are batched together, so short requests don't ride along with
/// full-length ones (the executed window is the batch's longest request).
fn bucket_of(len: usize) -> u32 {
    len.max(1).next_power_of_two().trailing_zeros()
}

// ------------------------------------------------------------ shared queue

struct QueueState {
    q: VecDeque<Request>,
    closed: bool,
}

/// Bounded MPMC queue feeding the workers (Mutex + two Condvars).
pub(crate) struct SharedQueue {
    cap: usize,
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl SharedQueue {
    fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            state: Mutex::new(QueueState { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocking push with backpressure; fails once the queue is closed.
    fn push_wait(&self, req: Request) -> std::result::Result<(), ScoreError> {
        let mut s = self.state.lock().unwrap();
        while !s.closed && s.q.len() >= self.cap {
            s = self.not_full.wait(s).unwrap();
        }
        if s.closed {
            return Err(ScoreError::Shutdown);
        }
        s.q.push_back(req);
        // notify_all, not notify_one: a single wakeup could land on a
        // bucket-filtered pop_matching waiter that refuses the item while
        // an idle pop_any worker sleeps (lost wakeup)
        self.not_empty.notify_all();
        Ok(())
    }

    /// Non-blocking push: `QueueFull` when at capacity.
    fn try_push(&self, req: Request) -> std::result::Result<(), ScoreError> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(ScoreError::Shutdown);
        }
        if s.q.len() >= self.cap {
            return Err(ScoreError::QueueFull);
        }
        s.q.push_back(req);
        self.not_empty.notify_all(); // see push_wait
        Ok(())
    }

    /// Blocking pop; `None` only once the queue is closed *and* drained —
    /// this is what makes shutdown drain in-flight requests.
    fn pop_any(&self) -> Option<Request> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(r) = s.q.pop_front() {
                self.not_full.notify_one();
                return Some(r);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Non-blocking pop (used to drain after a backend construction error).
    fn pop_now(&self) -> Option<Request> {
        let mut s = self.state.lock().unwrap();
        let r = s.q.pop_front();
        if r.is_some() {
            self.not_full.notify_one();
        }
        r
    }

    /// Pop the first request satisfying `pred`, waiting until `deadline`
    /// for one to arrive. The predicate is what keeps batches
    /// kind-homogeneous and (for generation) inside the token budget.
    fn pop_matching<P: Fn(&Request) -> bool>(
        &self,
        deadline: Instant,
        pred: P,
    ) -> Option<Request> {
        let mut s = self.state.lock().unwrap();
        loop {
            let idx = s.q.iter().position(|r| pred(r));
            if let Some(i) = idx {
                let r = s.q.remove(i);
                self.not_full.notify_one();
                return r;
            }
            if s.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _timed_out) =
                self.not_empty.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
    }

    fn close(&self) {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        drop(s);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    fn depth(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }
}

// ------------------------------------------------------------------ client

/// Handle for submitting requests (cheap to clone, thread-safe).
#[derive(Clone)]
pub struct Client {
    queue: Arc<SharedQueue>,
    metrics: Arc<Mutex<Metrics>>,
}

impl Client {
    /// Blocking score call: waits for queue space (backpressure), then for
    /// the response. Over-length and deadline violations come back as
    /// typed errors.
    pub fn score(&self, tokens: Vec<u32>) -> ScoreResult {
        self.submit(tokens, RequestKind::Score)
    }

    /// Blocking generate call: greedy continuation of `prompt` by up to
    /// `max_new_tokens` tokens (`Response::tokens`). Prompt + budget must
    /// fit the backend's seq capacity or `TooLong` comes back; a backend
    /// without a decode path answers `NotGenerative`.
    pub fn generate(&self, prompt: Vec<u32>, max_new_tokens: usize) -> ScoreResult {
        self.submit(
            prompt,
            RequestKind::Generate { max_new_tokens, temperature: 0.0, seed: 0 },
        )
    }

    /// [`generate`](Self::generate) with seeded temperature sampling
    /// (deterministic for a fixed seed; `temperature == 0.0` is greedy).
    pub fn generate_sampled(
        &self,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        temperature: f64,
        seed: u64,
    ) -> ScoreResult {
        self.submit(prompt, RequestKind::Generate { max_new_tokens, temperature, seed })
    }

    fn submit(&self, tokens: Vec<u32>, kind: RequestKind) -> ScoreResult {
        let (rtx, rrx) = std::sync::mpsc::channel();
        self.queue
            .push_wait(Request { tokens, kind, reply: rtx, enqueued: Instant::now() })?;
        match rrx.recv() {
            Ok(r) => r,
            Err(_) => Err(ScoreError::Shutdown),
        }
    }

    /// Like [`score`](Self::score), but rejects immediately with
    /// `QueueFull` instead of blocking when the queue is at capacity.
    pub fn try_score(&self, tokens: Vec<u32>) -> ScoreResult {
        let (rtx, rrx) = std::sync::mpsc::channel();
        let pushed = self.queue.try_push(Request {
            tokens,
            kind: RequestKind::Score,
            reply: rtx,
            enqueued: Instant::now(),
        });
        if let Err(e) = pushed {
            if e == ScoreError::QueueFull {
                self.metrics.lock().unwrap().rejected_queue_full += 1;
            }
            return Err(e);
        }
        match rrx.recv() {
            Ok(r) => r,
            Err(_) => Err(ScoreError::Shutdown),
        }
    }
}

// ------------------------------------------------------------------ server

/// A running scoring server: N workers over a shared bounded queue.
pub struct Server {
    queue: Arc<SharedQueue>,
    workers: Vec<JoinHandle<Result<()>>>,
    /// stamped when the first worker's backend is ready, so wall-clock
    /// throughput excludes backend construction/compile time (matching
    /// the pre-multi-worker benchmark semantics)
    serve_start: Arc<Mutex<Option<Instant>>>,
    pub metrics: Arc<Mutex<Metrics>>,
}

impl Server {
    /// Spawn `opts.workers` worker threads. `make_backend` runs once
    /// *inside each* worker thread (PJRT handles are not `Send` — same
    /// pattern as a GPU worker owning its CUDA context), so it must be a
    /// reusable `Fn`, typically borrowing a shared model.
    pub fn spawn<B, F>(make_backend: F, opts: ServerOpts) -> Self
    where
        B: ScoreBackend + 'static,
        F: Fn() -> Result<B> + Send + Sync + 'static,
    {
        if opts.threads > 0 {
            crate::util::parallel::set_threads(opts.threads);
        }
        let n = opts.workers.max(1);
        let queue = Arc::new(SharedQueue::new(opts.queue));
        let metrics = Arc::new(Mutex::new(Metrics {
            per_worker: vec![WorkerMetrics::default(); n],
            ..Default::default()
        }));
        let factory = Arc::new(make_backend);
        let serve_start: Arc<Mutex<Option<Instant>>> = Arc::new(Mutex::new(None));
        let mut workers = Vec::with_capacity(n);
        for id in 0..n {
            let f = factory.clone();
            let q = queue.clone();
            let m = metrics.clone();
            let o = opts.clone();
            let s = serve_start.clone();
            workers.push(std::thread::spawn(move || worker_loop(id, f, q, o, m, s)));
        }
        Self { queue, workers, serve_start, metrics }
    }

    pub fn client(&self) -> Client {
        Client { queue: self.queue.clone(), metrics: self.metrics.clone() }
    }

    /// Stop accepting requests, drain everything already queued, and join
    /// the workers. Returns the final metrics (or the first worker error).
    pub fn shutdown(mut self) -> Result<Metrics> {
        self.queue.close();
        let mut first_err: Option<anyhow::Error> = None;
        for h in self.workers.drain(..) {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!("worker thread panicked"));
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let mut m = self.metrics.lock().unwrap().clone();
        if let Some(t0) = *self.serve_start.lock().unwrap() {
            m.wall_secs = t0.elapsed().as_secs_f64();
        }
        Ok(m)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // a dropped server must not leave workers blocked on the queue
        self.queue.close();
    }
}

/// Spawn a server for a (possibly compressed) model on the named backend:
/// `"ref"` (pure-Rust batched forward, artifact-free) or `"xla"`
/// (runtime-compiled PJRT graph). Reference workers share one model `Arc`
/// and serve factored weights *directly* — a compressed model's removed
/// parameters are never rematerialized (no `to_dense()`, no `Reconstruct`
/// stage calls); a model with no factored types serves its dense base
/// weights. XLA workers each compile their own graph (PJRT handles are
/// `!Send`). The single seam every serving driver goes through (CLI,
/// examples, benches).
pub fn spawn_model_server(
    model: crate::model::lowrank::CompressedModel,
    batch: usize,
    seq: usize,
    backend: &str,
    opts: ServerOpts,
) -> Result<Server> {
    use crate::model::lowrank::TypeRep;
    match backend {
        "ref" => {
            if model.reps.values().any(|r| matches!(r, TypeRep::Factored(_))) {
                let m = Arc::new(model);
                Ok(Server::spawn(
                    move || Ok(RefBackend::factored(m.clone(), batch, seq)),
                    opts,
                ))
            } else {
                let w = Arc::new(model.base);
                Ok(Server::spawn(
                    move || Ok(RefBackend::shared(w.clone(), batch, seq)),
                    opts,
                ))
            }
        }
        "xla" => Ok(Server::spawn(
            move || {
                let rt = crate::runtime::Runtime::cpu()?;
                crate::graph::compile_forward(&rt, &model, batch, seq)
            },
            opts,
        )),
        other => anyhow::bail!("unknown backend '{other}' (expected xla or ref)"),
    }
}

// ------------------------------------------------------------ worker loop

struct WorkerCtx {
    id: usize,
    seq: usize,
    vocab: Option<usize>,
    deadline: Option<Duration>,
    /// whether the backend exposes a [`GenerateBackend`] seam — when it
    /// doesn't, `Generate` requests are rejected here, typed, per request
    can_generate: bool,
    metrics: Arc<Mutex<Metrics>>,
}

impl WorkerCtx {
    /// Admission control: replies (and counts) rejections, passes the rest.
    fn screen(&self, req: Request) -> Option<Request> {
        if req.is_generate() && !self.can_generate {
            self.metrics.lock().unwrap().rejected_not_generative += 1;
            let _ = req.reply.send(Err(ScoreError::NotGenerative));
            return None;
        }
        // admission prices the request's *total* footprint: for generation
        // that is prompt + max_new (the KV cache it will fill), so an
        // over-budget ask is rejected up front rather than truncated
        if req.total_len() > self.seq {
            self.metrics.lock().unwrap().rejected_too_long += 1;
            let _ = req.reply.send(Err(ScoreError::TooLong {
                len: req.total_len(),
                seq: self.seq,
            }));
            return None;
        }
        if let Some(v) = self.vocab {
            if let Some(&bad) = req.tokens.iter().find(|&&t| t as usize >= v) {
                self.metrics.lock().unwrap().rejected_invalid_token += 1;
                let _ = req
                    .reply
                    .send(Err(ScoreError::InvalidToken { id: bad, vocab: v }));
                return None;
            }
        }
        if let Some(d) = self.deadline {
            if req.enqueued.elapsed() > d {
                self.metrics.lock().unwrap().rejected_timeout += 1;
                let _ = req.reply.send(Err(ScoreError::Timeout));
                return None;
            }
        }
        Some(req)
    }
}

/// Closes *and drains* the queue when a worker exits for any reason —
/// including a panic unwinding out of the backend. Without this, a dead
/// worker would leave requests queued (their clients blocked in `recv`
/// forever) and later `score()` calls would block on an open queue. On a
/// normal exit the queue is already closed and empty, so this is a no-op;
/// with several workers the healthy ones race this drain and serve what
/// they grab first, which is fine — the server is going down either way.
struct CloseOnExit(Arc<SharedQueue>);

impl Drop for CloseOnExit {
    fn drop(&mut self) {
        self.0.close();
        while let Some(req) = self.0.pop_now() {
            let _ = req.reply.send(Err(ScoreError::Shutdown));
        }
    }
}

fn worker_loop<B, F>(
    id: usize,
    factory: Arc<F>,
    queue: Arc<SharedQueue>,
    opts: ServerOpts,
    metrics: Arc<Mutex<Metrics>>,
    serve_start: Arc<Mutex<Option<Instant>>>,
) -> Result<()>
where
    B: ScoreBackend,
    F: Fn() -> Result<B>,
{
    let _close_guard = CloseOnExit(queue.clone());
    let backend = match (*factory)() {
        Ok(b) => b,
        Err(e) => {
            // fail fast: no backend means nobody may be left waiting
            queue.close();
            while let Some(req) = queue.pop_now() {
                let _ = req.reply.send(Err(ScoreError::Backend(e.to_string())));
            }
            return Err(e);
        }
    };
    // release the factory (and whatever model it captured) once the
    // backend exists — the last worker to construct frees the captures,
    // matching the old FnOnce behavior instead of pinning the model for
    // the server's whole lifetime
    drop(factory);
    // the serving wall clock starts when the first backend is ready:
    // construction/compile time must not count into throughput
    let started = {
        let mut s = serve_start.lock().unwrap();
        *s.get_or_insert_with(Instant::now)
    };
    let (bsz, seq) = (backend.batch(), backend.seq());
    let ctx = WorkerCtx {
        id,
        seq,
        vocab: backend.vocab(),
        deadline: opts.deadline,
        can_generate: backend.generator().is_some(),
        metrics: metrics.clone(),
    };
    loop {
        // block for the first admissible request of the batch
        let first = loop {
            match queue.pop_any() {
                None => {
                    // queue closed + drained: record wall time and exit
                    let mut m = metrics.lock().unwrap();
                    m.wall_secs = started.elapsed().as_secs_f64();
                    return Ok(());
                }
                Some(r) => {
                    if let Some(ok) = ctx.screen(r) {
                        break ok;
                    }
                }
            }
        };
        let depth = queue.depth();
        if first.is_generate() {
            serve_generate_batch(&backend, first, &queue, &opts, &ctx, depth, bsz, seq);
            metrics.lock().unwrap().wall_secs = started.elapsed().as_secs_f64();
            continue;
        }
        // bucketing only pays off when the backend can shrink its window;
        // a fixed-shape graph runs full [batch, seq] regardless, so
        // fragmenting its batches by length would only hurt occupancy
        let bucket = if opts.bucket_by_length && backend.is_shape_flexible() {
            Some(bucket_of(first.tokens.len()))
        } else {
            None
        };
        let mut batch = vec![first];
        // fill the rest of the batch (same-kind, same length bucket)
        // within the window
        let fill_deadline = Instant::now() + opts.batch_window;
        while batch.len() < bsz {
            let popped = queue.pop_matching(fill_deadline, |r| {
                !r.is_generate()
                    && bucket.is_none_or(|bk| bucket_of(r.tokens.len()) == bk)
            });
            match popped {
                None => break,
                Some(r) => {
                    if let Some(ok) = ctx.screen(r) {
                        batch.push(ok);
                    }
                }
            }
        }
        // shrink the executed window to the longest request in the batch
        // (length bucketing makes batches share a small window), pad rows
        // to it, and execute only the occupied rows
        let rows = batch.len();
        let used_seq = batch
            .iter()
            .map(|r| r.tokens.len())
            .max()
            .unwrap_or(2)
            .clamp(2, seq);
        let mut tokens = vec![0i32; rows * used_seq];
        for (row, req) in batch.iter().enumerate() {
            for (i, &t) in req.tokens.iter().enumerate() {
                tokens[row * used_seq + i] = t as i32;
            }
        }
        let busy = Instant::now();
        let result = backend.nll_window(&tokens, rows, used_seq);
        let busy_secs = busy.elapsed().as_secs_f64();
        // slots the backend actually executed: a fixed-shape compiled
        // graph always runs its full [batch, seq] window
        let executed_slots = if backend.is_shape_flexible() {
            rows * used_seq
        } else {
            bsz * seq
        };

        // reply outside the metrics lock: the response path must not
        // serialize across workers
        let mut served: Vec<(usize, f64)> = Vec::with_capacity(rows);
        match result {
            Ok(nll) => {
                for (row, req) in batch.into_iter().enumerate() {
                    let n = req.tokens.len();
                    let start = row * (used_seq - 1);
                    let row_nll = nll[start..start + n.saturating_sub(1)].to_vec();
                    let latency_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
                    served.push((n, latency_ms));
                    let _ = req.reply.send(Ok(Response { nll: row_nll, latency_ms, worker: id }));
                }
            }
            Err(e) => {
                for req in batch {
                    let _ = req.reply.send(Err(ScoreError::Backend(e.to_string())));
                }
            }
        }

        let mut m = metrics.lock().unwrap();
        m.batches += 1;
        m.busy_secs += busy_secs;
        m.queue_depth_sum += depth;
        m.queue_depth_samples += 1;
        m.padded_tokens += executed_slots;
        m.per_worker[id].batches += 1;
        m.per_worker[id].busy_secs += busy_secs;
        for &(n, latency_ms) in &served {
            m.requests += 1;
            m.tokens += n;
            m.latencies_ms.push(latency_ms);
            m.per_worker[id].requests += 1;
            m.per_worker[id].tokens += n;
        }
        m.wall_secs = started.elapsed().as_secs_f64();
    }
}

/// Assemble and serve one generation batch. The first request is already
/// admitted; the fill pulls only other `Generate` requests whose *total*
/// length (prompt + max_new) shares its bucket and fits the remaining
/// token budget (`batch * seq` slots per dispatch — the same capacity a
/// scoring batch occupies). Decode is a single-sequence path, so the
/// batch executes sequentially; batching still amortizes queue latency
/// and keeps admission/bucketing uniform with scoring.
#[allow(clippy::too_many_arguments)]
fn serve_generate_batch<B: ScoreBackend>(
    backend: &B,
    first: Request,
    queue: &SharedQueue,
    opts: &ServerOpts,
    ctx: &WorkerCtx,
    depth: usize,
    bsz: usize,
    seq: usize,
) {
    let generator = backend.generator().expect("screened: backend generates");
    let budget = bsz * seq;
    let bucket =
        if opts.bucket_by_length { Some(bucket_of(first.total_len())) } else { None };
    let mut total = first.total_len();
    let mut batch = vec![first];
    let fill_deadline = Instant::now() + opts.batch_window;
    while batch.len() < bsz && total < budget {
        let room = budget - total;
        let popped = queue.pop_matching(fill_deadline, |r| {
            r.is_generate()
                && r.total_len() <= room
                && bucket.is_none_or(|bk| bucket_of(r.total_len()) == bk)
        });
        match popped {
            None => break,
            Some(r) => {
                if let Some(ok) = ctx.screen(r) {
                    total += ok.total_len();
                    batch.push(ok);
                }
            }
        }
    }
    let busy = Instant::now();
    // (prompt len, generated len, latency) per successfully served request
    let mut served: Vec<(usize, usize, f64)> = Vec::with_capacity(batch.len());
    for req in batch {
        let prompt: Vec<i32> = req.tokens.iter().map(|&t| t as i32).collect();
        let RequestKind::Generate { max_new_tokens, temperature, seed } = req.kind else {
            unreachable!("generate batches are kind-homogeneous");
        };
        let gopts =
            crate::model::fwd::GenerateOpts { max_new_tokens, temperature, seed };
        match generator.generate(&prompt, &gopts) {
            Ok(new_tokens) => {
                let latency_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
                served.push((prompt.len(), new_tokens.len(), latency_ms));
                let _ = req.reply.send(Ok(Response {
                    nll: Vec::new(),
                    tokens: new_tokens,
                    latency_ms,
                    worker: ctx.id,
                }));
            }
            Err(e) => {
                let _ = req.reply.send(Err(ScoreError::Backend(e.to_string())));
            }
        }
    }
    let busy_secs = busy.elapsed().as_secs_f64();
    let mut m = ctx.metrics.lock().unwrap();
    m.batches += 1;
    m.busy_secs += busy_secs;
    m.queue_depth_sum += depth;
    m.queue_depth_samples += 1;
    m.per_worker[ctx.id].batches += 1;
    m.per_worker[ctx.id].busy_secs += busy_secs;
    for &(prompt_len, new_len, latency_ms) in &served {
        m.requests += 1;
        m.tokens += prompt_len + new_len;
        m.generated_tokens += new_len;
        // decode executes exactly the slots it fills — no padding waste
        m.padded_tokens += prompt_len + new_len;
        m.latencies_ms.push(latency_ms);
        m.per_worker[ctx.id].requests += 1;
        m.per_worker[ctx.id].tokens += prompt_len + new_len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_math() {
        let m = Metrics {
            requests: 10,
            tokens: 960,
            batches: 4,
            latencies_ms: vec![1.0, 2.0, 3.0, 4.0],
            busy_secs: 0.5,
            wall_secs: 2.0,
            padded_tokens: 1280,
            queue_depth_sum: 6,
            queue_depth_samples: 3,
            ..Default::default()
        };
        assert!((m.throughput_tps() - 480.0).abs() < 1e-9);
        assert_eq!(m.mean_batch_occupancy(), 2.5);
        assert!(m.p50_ms() >= 1.0 && m.p99_ms() <= 4.0);
        assert!((m.padding_efficiency() - 0.75).abs() < 1e-9);
        assert!((m.mean_queue_depth() - 2.0).abs() < 1e-9);
        assert_eq!(m.rejected(), 0);
    }

    #[test]
    fn buckets_group_similar_lengths() {
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), bucket_of(4));
        assert_eq!(bucket_of(33), bucket_of(64));
        assert_ne!(bucket_of(32), bucket_of(33));
        assert_eq!(bucket_of(0), bucket_of(1)); // empty requests don't panic
    }

    fn req(len: usize) -> (Request, std::sync::mpsc::Receiver<ScoreResult>) {
        let (tx, rx) = std::sync::mpsc::channel();
        (
            Request {
                tokens: vec![1; len],
                kind: RequestKind::Score,
                reply: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    fn gen_req(
        len: usize,
        max_new: usize,
    ) -> (Request, std::sync::mpsc::Receiver<ScoreResult>) {
        let (tx, rx) = std::sync::mpsc::channel();
        (
            Request {
                tokens: vec![1; len],
                kind: RequestKind::Generate {
                    max_new_tokens: max_new,
                    temperature: 0.0,
                    seed: 0,
                },
                reply: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn queue_capacity_and_close_semantics() {
        let q = SharedQueue::new(2);
        let (r1, _k1) = req(4);
        let (r2, _k2) = req(4);
        let (r3, _k3) = req(4);
        q.try_push(r1).unwrap();
        q.try_push(r2).unwrap();
        assert_eq!(q.try_push(r3).unwrap_err(), ScoreError::QueueFull);
        assert_eq!(q.depth(), 2);
        q.close();
        let (r4, _k4) = req(4);
        assert_eq!(q.try_push(r4).unwrap_err(), ScoreError::Shutdown);
        // closed queues still drain (shutdown semantics)
        assert!(q.pop_any().is_some());
        assert!(q.pop_any().is_some());
        assert!(q.pop_any().is_none());
    }

    #[test]
    fn pop_matching_prefers_bucket() {
        let q = SharedQueue::new(8);
        let (long, _kl) = req(60); // bucket 6
        let (short, _ks) = req(3); // bucket 2
        q.try_push(long).unwrap();
        q.try_push(short).unwrap();
        let deadline = Instant::now() + Duration::from_millis(5);
        let want = bucket_of(3);
        let got =
            q.pop_matching(deadline, |r| bucket_of(r.tokens.len()) == want).unwrap();
        assert_eq!(got.tokens.len(), 3); // skipped the longer request
        assert_eq!(q.depth(), 1);
        // no match in bucket -> times out without popping
        let deadline = Instant::now() + Duration::from_millis(5);
        assert!(q.pop_matching(deadline, |r| bucket_of(r.tokens.len()) == want).is_none());
        assert_eq!(q.depth(), 1);
        // unfiltered pop takes whatever is first
        let deadline = Instant::now() + Duration::from_millis(5);
        assert_eq!(q.pop_matching(deadline, |_| true).unwrap().tokens.len(), 60);
    }

    #[test]
    fn pop_matching_keeps_batches_kind_homogeneous() {
        let q = SharedQueue::new(8);
        let (score, _ks) = req(6);
        let (gen, _kg) = gen_req(6, 4);
        q.try_push(score).unwrap();
        q.try_push(gen).unwrap();
        // a generate fill skips the score request at the head of the queue
        let deadline = Instant::now() + Duration::from_millis(5);
        let got = q.pop_matching(deadline, |r| r.is_generate()).unwrap();
        assert!(got.is_generate());
        assert_eq!(got.total_len(), 10); // prompt 6 + max_new 4
        // and a score fill never drains a generate request
        let (gen2, _kg2) = gen_req(6, 4);
        q.try_push(gen2).unwrap();
        let deadline = Instant::now() + Duration::from_millis(5);
        let got = q.pop_matching(deadline, |r| !r.is_generate()).unwrap();
        assert_eq!(got.kind, RequestKind::Score);
    }

    #[test]
    fn generate_fill_respects_the_token_budget() {
        // the worker's fill predicate: total_len must fit the remaining room
        let q = SharedQueue::new(8);
        let (big, _kb) = gen_req(20, 20); // total 40
        let (small, _ks) = gen_req(4, 4); // total 8
        q.try_push(big).unwrap();
        q.try_push(small).unwrap();
        let room = 10usize;
        let deadline = Instant::now() + Duration::from_millis(5);
        let got = q
            .pop_matching(deadline, |r| r.is_generate() && r.total_len() <= room)
            .unwrap();
        assert_eq!(got.total_len(), 8);
        assert_eq!(q.depth(), 1); // the over-budget request stays queued
    }

    /// A scoring-only backend: `generator()` stays at the trait default.
    struct ScoreOnly;

    impl ScoreBackend for ScoreOnly {
        fn batch(&self) -> usize {
            2
        }
        fn seq(&self) -> usize {
            16
        }
        fn nll(&self, tokens: &[i32]) -> Result<Vec<f32>> {
            Ok(vec![0.0; tokens.len() - tokens.len() / 16])
        }
    }

    #[test]
    fn generate_on_a_scoring_only_backend_is_rejected_typed() {
        let server = Server::spawn(|| Ok(ScoreOnly), ServerOpts::default());
        let client = server.client();
        let got = client.generate(vec![1, 2, 3], 4);
        assert_eq!(got.unwrap_err(), ScoreError::NotGenerative);
        let m = server.shutdown().unwrap();
        assert_eq!(m.rejected_not_generative, 1);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.requests, 0);
    }
}
