//! The `ScoreBackend` seam: what a serving worker needs from a forward
//! implementation, decoupled from how the forward runs.
//!
//! Two implementations ship:
//!  - [`CompiledForward`] (the runtime-built XLA graph over PJRT) — the
//!    production path the paper's throughput numbers come from;
//!  - [`RefBackend`] — the pure-Rust batched forward (`model::fwd`),
//!    which needs no artifacts, no PJRT, and is `Send`-free-constructible
//!    inside any worker thread. It scores either dense weights
//!    ([`RefBackend::new`]/[`RefBackend::shared`]) or a compressed model's
//!    factors directly ([`RefBackend::factored`] → `fwd::nll_model`,
//!    never materializing dense weights), and is both the test oracle for
//!    the coordinator suite and a real serving backend: unlike the
//!    fixed-shape compiled graph it can score partial batches without
//!    padding them out to full batch capacity.
//!
//! Workers construct their backend *inside* the worker thread via the
//! factory passed to `Server::spawn` (PJRT handles are `!Send`).

use std::sync::Arc;

use anyhow::Result;

use crate::graph::CompiledForward;
use crate::model::fwd::GenerateOpts;
use crate::model::lowrank::CompressedModel;
use crate::model::{fwd, Weights};

/// A batched scoring backend: fixed `[batch, seq]` windows in, per-token
/// NLL out. Implementations must be usable from the single worker thread
/// that constructed them (no `Send` bound — PJRT handles are `!Send`).
pub trait ScoreBackend {
    /// Maximum rows per call.
    fn batch(&self) -> usize;

    /// Fixed (padded) tokens per row; NLL rows have `seq() - 1` entries.
    fn seq(&self) -> usize;

    /// Vocabulary size, when known: the coordinator rejects requests with
    /// out-of-range token ids *per request* (typed `InvalidToken`) instead
    /// of letting one malformed id fail — or crash — a whole batch.
    fn vocab(&self) -> Option<usize> {
        None
    }

    /// Score a full `[batch, seq]` token window -> `[batch, seq-1]` NLL.
    fn nll(&self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Can this backend execute shapes smaller than `[batch, seq]`?
    /// Shape-flexible backends run partial/short batches at reduced cost;
    /// a compiled graph always executes its full fixed shape (this drives
    /// the coordinator's executed-slot accounting).
    fn is_shape_flexible(&self) -> bool {
        false
    }

    /// Score `rows <= batch` rows, each padded to `used_seq` (2..=seq)
    /// tokens; `tokens` is `[rows, used_seq]`, result `[rows, used_seq-1]`.
    /// The default re-pads to the fixed `[batch, seq]` shape a compiled
    /// graph requires and slices the result back down; shape-flexible
    /// backends override this to skip the padded work entirely.
    fn nll_window(&self, tokens: &[i32], rows: usize, used_seq: usize) -> Result<Vec<f32>> {
        let (b, s) = (self.batch(), self.seq());
        assert!(rows >= 1 && rows <= b, "rows {rows} out of 1..={b}");
        assert!((2..=s).contains(&used_seq), "used_seq {used_seq} out of 2..={s}");
        assert_eq!(tokens.len(), rows * used_seq, "tokens must be [rows, used_seq]");
        if rows == b && used_seq == s {
            // already the fixed shape: the pad copy and the slice-down are
            // both identities, so skip cloning the token buffer entirely
            return self.nll(tokens);
        }
        let mut padded = vec![0i32; b * s];
        for r in 0..rows {
            padded[r * s..r * s + used_seq]
                .copy_from_slice(&tokens[r * used_seq..(r + 1) * used_seq]);
        }
        let full = self.nll(&padded)?;
        let mut out = Vec::with_capacity(rows * (used_seq - 1));
        for r in 0..rows {
            out.extend_from_slice(&full[r * (s - 1)..r * (s - 1) + (used_seq - 1)]);
        }
        Ok(out)
    }

    /// The generation seam, when this backend has one. The coordinator
    /// routes `Generate` requests through this; backends without a decode
    /// path (the fixed-shape compiled graph) return `None` and the worker
    /// rejects such requests with a typed error instead of panicking.
    fn generator(&self) -> Option<&dyn GenerateBackend> {
        None
    }
}

/// The generation seam beside [`ScoreBackend`]: autoregressive prompt →
/// tokens, backed by the KV-cached prefill/decode path (`model::fwd`).
/// Same thread-locality contract as [`ScoreBackend`] (no `Send` bound).
pub trait GenerateBackend {
    /// Maximum total tokens per sequence (prompt + generated) — the
    /// worker's admission budget for `Generate` requests.
    fn max_tokens(&self) -> usize;

    /// Generate up to `opts.max_new_tokens` tokens after `prompt`,
    /// returning only the new tokens.
    fn generate(&self, prompt: &[i32], opts: &GenerateOpts) -> Result<Vec<i32>>;
}

impl ScoreBackend for CompiledForward {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn vocab(&self) -> Option<usize> {
        Some(self.vocab)
    }

    fn nll(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        CompiledForward::nll(self, tokens)
    }
}

/// Weight source of a [`RefBackend`]: plain dense weights, or a compressed
/// model served on its factors.
enum RefModel {
    Dense(Arc<Weights>),
    Factored(Arc<CompressedModel>),
}

impl RefModel {
    fn config(&self) -> &crate::model::ModelConfig {
        match self {
            RefModel::Dense(w) => &w.config,
            RefModel::Factored(m) => &m.base.config,
        }
    }

    fn nll(&self, tokens: &[i32], rows: usize, seq: usize) -> Vec<f32> {
        match self {
            RefModel::Dense(w) => fwd::nll(w, tokens, rows, seq),
            RefModel::Factored(m) => fwd::nll_model(m, tokens, rows, seq),
        }
    }
}

/// Pure-Rust reference backend: dense weights, or a compressed model whose
/// factored sites execute `(x·B)·C` directly — serving never calls
/// `to_dense()`. Runs with no `artifacts/` directory and no PJRT.
pub struct RefBackend {
    model: RefModel,
    batch: usize,
    seq: usize,
}

impl RefBackend {
    pub fn new(weights: Weights, batch: usize, seq: usize) -> Self {
        Self::shared(Arc::new(weights), batch, seq)
    }

    /// Share one weight set across N workers (the reference forward is
    /// pure Rust, so unlike PJRT handles it can be shared freely) — an
    /// N-worker server should reconstruct/load once and pass clones of
    /// the `Arc` instead of paying N copies.
    pub fn shared(weights: Arc<Weights>, batch: usize, seq: usize) -> Self {
        Self::build(RefModel::Dense(weights), batch, seq)
    }

    /// Serve a compressed model on its factors: every factored projection
    /// runs as two skinny GEMMs through the `Linear` operator and the
    /// removed parameters are never rematerialized (profile stage
    /// `fwd_lowrank` counts these; `reconstruct` stays at zero).
    pub fn factored(model: Arc<CompressedModel>, batch: usize, seq: usize) -> Self {
        Self::build(RefModel::Factored(model), batch, seq)
    }

    fn build(model: RefModel, batch: usize, seq: usize) -> Self {
        assert!(batch >= 1, "batch must be >= 1");
        assert!(seq >= 2, "seq must be >= 2 (NLL predicts positions 1..seq)");
        Self { model, batch, seq }
    }

    /// The reference forward indexes the embedding by raw token id, so an
    /// out-of-range id would panic deep inside the forward — turn it into
    /// an error here (the coordinator normally screens ids first; this is
    /// the belt-and-suspenders for direct library users).
    fn check_tokens(&self, tokens: &[i32]) -> Result<()> {
        let v = self.model.config().vocab;
        if let Some(&bad) = tokens.iter().find(|&&t| t < 0 || t as usize >= v) {
            anyhow::bail!("token id {bad} outside vocabulary of {v}");
        }
        Ok(())
    }
}

impl ScoreBackend for RefBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn vocab(&self) -> Option<usize> {
        Some(self.model.config().vocab)
    }

    fn nll(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == self.batch * self.seq,
            "tokens must be [batch={}, seq={}]",
            self.batch,
            self.seq
        );
        self.check_tokens(tokens)?;
        Ok(self.model.nll(tokens, self.batch, self.seq))
    }

    fn is_shape_flexible(&self) -> bool {
        true
    }

    /// Partial/short batches run at `[rows, used_seq]` cost — the
    /// reference forward takes any shape, so no padding is ever computed.
    fn nll_window(&self, tokens: &[i32], rows: usize, used_seq: usize) -> Result<Vec<f32>> {
        assert!(rows >= 1 && rows <= self.batch, "rows {rows} out of 1..={}", self.batch);
        assert!(
            (2..=self.seq).contains(&used_seq),
            "used_seq {used_seq} out of 2..={}",
            self.seq
        );
        assert_eq!(tokens.len(), rows * used_seq, "tokens must be [rows, used_seq]");
        self.check_tokens(tokens)?;
        Ok(self.model.nll(tokens, rows, used_seq))
    }

    fn generator(&self) -> Option<&dyn GenerateBackend> {
        Some(self)
    }
}

impl GenerateBackend for RefBackend {
    fn max_tokens(&self) -> usize {
        self.seq
    }

    /// KV-cached generation on whichever representation this backend
    /// serves: dense weights, or a compressed model's factors (never
    /// reconstructing dense weights — the same zero-`Reconstruct` property
    /// as scoring, asserted in `rust/tests/decode.rs`).
    fn generate(&self, prompt: &[i32], opts: &GenerateOpts) -> Result<Vec<i32>> {
        anyhow::ensure!(!prompt.is_empty(), "generate needs a non-empty prompt");
        anyhow::ensure!(
            prompt.len() + opts.max_new_tokens <= self.seq,
            "prompt ({}) + max_new_tokens ({}) exceeds the {}-token budget",
            prompt.len(),
            opts.max_new_tokens,
            self.seq
        );
        self.check_tokens(prompt)?;
        Ok(match &self.model {
            RefModel::Dense(w) => fwd::generate(w, prompt, opts),
            RefModel::Factored(m) => fwd::generate_model(m, prompt, opts),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn ref_backend_matches_direct_forward() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 11);
        let be = RefBackend::new(w.clone(), cfg.batch, cfg.seq);
        let toks: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
        let got = be.nll(&toks).unwrap();
        let want = fwd::nll(&w, &toks, cfg.batch, cfg.seq);
        assert_eq!(got, want);
        assert_eq!(got.len(), cfg.batch * (cfg.seq - 1));
    }

    #[test]
    fn partial_rows_match_full_batch_rows() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 12);
        let be = RefBackend::new(w, cfg.batch, cfg.seq);
        let toks: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
        let full = be.nll(&toks).unwrap();
        let one = be.nll_window(&toks[..cfg.seq], 1, cfg.seq).unwrap();
        assert_eq!(one.len(), cfg.seq - 1);
        // rows are independent in the reference forward: bitwise identical
        assert_eq!(one, full[..cfg.seq - 1].to_vec());
    }

    #[test]
    fn shortened_window_matches_full_padding() {
        // causality: a [1, used_seq] window equals the first used_seq-1
        // NLLs of the zero-padded full-seq row
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 13);
        let be = RefBackend::new(w.clone(), cfg.batch, cfg.seq);
        let len = 10usize;
        let toks: Vec<i32> = (1..=len as i32).collect();
        let small = be.nll_window(&toks, 1, len).unwrap();
        assert_eq!(small.len(), len - 1);
        let mut padded = vec![0i32; cfg.seq];
        padded[..len].copy_from_slice(&toks);
        let full = fwd::nll(&w, &padded, 1, cfg.seq);
        for i in 0..len - 1 {
            assert!((small[i] - full[i]).abs() < 1e-6, "position {i}");
        }
    }

    /// RefBackend with the trait's *default* (fixed-shape) window path.
    struct FixedShape(RefBackend);

    impl ScoreBackend for FixedShape {
        fn batch(&self) -> usize {
            self.0.batch()
        }
        fn seq(&self) -> usize {
            self.0.seq()
        }
        fn nll(&self, tokens: &[i32]) -> Result<Vec<f32>> {
            self.0.nll(tokens)
        }
    }

    #[test]
    fn factored_backend_matches_dense_reconstruction() {
        // serve the same compressed model both ways: on its factors and on
        // the reconstructed dense weights — scores must agree to f32
        // association tolerance (the never-calls-Reconstruct property is
        // asserted in rust/tests/coordinator.rs, where stage counters
        // aren't raced by unrelated lib tests)
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 15);
        let stats = crate::calib::CalibStats::synthetic(&cfg, 9);
        let opts = crate::compress::CompressOpts {
            method: crate::compress::Method::DRank,
            ratio: 0.3,
            group_layers: 2,
            ..Default::default()
        };
        let (model, _) = crate::compress::methods::compress(&w, &stats, &opts).unwrap();
        let dense = RefBackend::new(model.to_dense(), cfg.batch, cfg.seq);
        let fact = RefBackend::factored(Arc::new(model), cfg.batch, cfg.seq);
        let toks: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|i| ((i * 7) % cfg.vocab) as i32).collect();
        let a = fact.nll(&toks).unwrap();
        let b = dense.nll(&toks).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-2, "{x} vs {y}");
        }
    }

    /// Fixed-shape backend that records the address of the token buffer
    /// it is handed, to observe whether `nll_window` cloned it.
    struct PtrProbe {
        inner: RefBackend,
        seen: std::cell::Cell<*const i32>,
    }

    impl ScoreBackend for PtrProbe {
        fn batch(&self) -> usize {
            self.inner.batch()
        }
        fn seq(&self) -> usize {
            self.inner.seq()
        }
        fn nll(&self, tokens: &[i32]) -> Result<Vec<f32>> {
            self.seen.set(tokens.as_ptr());
            self.inner.nll(tokens)
        }
    }

    #[test]
    fn full_shape_window_skips_the_pad_clone() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 16);
        let be = PtrProbe {
            inner: RefBackend::new(w, cfg.batch, cfg.seq),
            seen: std::cell::Cell::new(std::ptr::null()),
        };
        let toks: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|i| ((i * 3) % cfg.vocab) as i32).collect();
        let windowed = be.nll_window(&toks, cfg.batch, cfg.seq).unwrap();
        // the fast path hands the caller's buffer straight through
        assert_eq!(be.seen.get(), toks.as_ptr(), "full-shape window must not clone tokens");
        let direct = be.nll(&toks).unwrap();
        assert_eq!(windowed, direct);
        // a genuinely partial window still goes through the padded copy
        let part = be.nll_window(&toks[..8], 1, 8).unwrap();
        assert_ne!(be.seen.get(), toks.as_ptr());
        assert_eq!(part.len(), 7);
    }

    #[test]
    fn generator_seam_is_some_for_ref_and_none_for_fixed() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 17);
        let be = RefBackend::new(w.clone(), cfg.batch, cfg.seq);
        let fixed = FixedShape(RefBackend::new(w.clone(), cfg.batch, cfg.seq));
        assert!(fixed.generator().is_none(), "default seam must opt out");
        let g = be.generator().expect("RefBackend generates");
        assert_eq!(g.max_tokens(), cfg.seq);
        let prompt: Vec<i32> = (1..=6).collect();
        let opts = GenerateOpts { max_new_tokens: 4, ..Default::default() };
        let got = g.generate(&prompt, &opts).unwrap();
        assert_eq!(got, fwd::generate(&w, &prompt, &opts));
        // typed rejection, not a panic, when the budget is exceeded
        let over = GenerateOpts { max_new_tokens: cfg.seq, ..Default::default() };
        assert!(g.generate(&prompt, &over).is_err());
        assert!(g.generate(&[], &opts).is_err());
        assert!(g.generate(&[cfg.vocab as i32], &opts).is_err());
    }

    #[test]
    fn default_window_impl_matches_flexible_override() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 14);
        let flex = RefBackend::new(w.clone(), cfg.batch, cfg.seq);
        let fixed = FixedShape(RefBackend::new(w, cfg.batch, cfg.seq));
        assert!(flex.is_shape_flexible());
        assert!(!fixed.is_shape_flexible());
        let used_seq = 8usize;
        let toks: Vec<i32> = (0..2 * used_seq).map(|i| (i % cfg.vocab) as i32).collect();
        let a = flex.nll_window(&toks, 2, used_seq).unwrap();
        let b = fixed.nll_window(&toks, 2, used_seq).unwrap();
        assert_eq!(a.len(), 2 * (used_seq - 1));
        assert_eq!(b.len(), a.len());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
