//! Experiment reporting: markdown tables shaped like the paper's, plus
//! persisted JSON result manifests under runs/reports/.

use std::fmt::Write as _;

use crate::util::json::Json;

/// A simple markdown table builder.
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as github markdown.
    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {c:<w$} |");
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Persist as JSON under runs/reports/<name>.json (stable format the
    /// EXPERIMENTS.md comparisons are built from).
    pub fn save_json(&self, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("runs/reports")?;
        let j = Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "header",
                Json::Arr(self.header.iter().map(|h| Json::str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(format!("runs/reports/{name}.json"), j.emit())
    }
}

/// Format a PPL value (3 decimals at tinylm scale — method separations on
/// a 763k-param substrate are O(0.01-0.1) PPL, vs the paper's O(1); big
/// values print as integers like the paper's diverged baselines).
pub fn fmt_ppl(x: f64) -> String {
    if !x.is_finite() {
        "inf".to_string()
    } else if x >= 1000.0 {
        format!("{x:.0}")
    } else {
        format!("{x:.3}")
    }
}

/// Format an accuracy like the paper (two decimals of fraction).
pub fn fmt_acc(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["Method", "PPL"]);
        t.row(vec!["SVD-LLM".into(), "7.94".into()]);
        t.row(vec!["D-Rank".into(), "7.45".into()]);
        let md = t.markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| SVD-LLM | 7.94 |"));
        assert!(md.lines().count() >= 5);
    }

    #[test]
    fn ppl_formatting() {
        assert_eq!(fmt_ppl(7.4499), "7.450");
        assert_eq!(fmt_ppl(20061.4), "20061");
        assert_eq!(fmt_ppl(f64::INFINITY), "inf");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
