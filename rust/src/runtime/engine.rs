//! Artifact registry + executable cache.
//!
//! Reads `artifacts/manifest.json` (written by `python -m compile.aot`),
//! validates Rust-side shape configs against the manifest, and lazily
//! compiles artifacts on first use. Compiled executables are cached for
//! the process lifetime — the serving/eval/training hot loops never touch
//! the HLO parser again.

use std::cell::RefCell;
use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use super::{lit_f32, Runtime};
use crate::model::{ModelConfig, Weights};
use crate::util::json::Json;

/// Parsed manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub config: String,
    pub kind: String,
    pub inputs: Vec<(String, Vec<usize>, String)>,
    pub outputs: Vec<(String, Vec<usize>, String)>,
}

fn parse_io(j: &Json) -> Option<Vec<(String, Vec<usize>, String)>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            Some((
                e.get("name")?.as_str()?.to_string(),
                e.get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Option<Vec<_>>>()?,
                e.get("dtype")?.as_str()?.to_string(),
            ))
        })
        .collect()
}

/// Runtime engine: PJRT client + manifest + compile cache.
pub struct Engine {
    pub rt: Runtime,
    pub dir: String,
    specs: BTreeMap<(String, String), ArtifactSpec>,
    cache: RefCell<BTreeMap<(String, String), std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: &str) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let mtext = std::fs::read_to_string(format!("{dir}/manifest.json"))
            .with_context(|| format!("reading {dir}/manifest.json — run `make artifacts`"))?;
        let j = Json::parse(&mtext).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut specs = BTreeMap::new();
        for a in j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let spec = ArtifactSpec {
                file: a.get("file").and_then(|x| x.as_str()).unwrap_or("").into(),
                config: a.get("config").and_then(|x| x.as_str()).unwrap_or("").into(),
                kind: a.get("kind").and_then(|x| x.as_str()).unwrap_or("").into(),
                inputs: a
                    .get("inputs")
                    .and_then(parse_io)
                    .ok_or_else(|| anyhow!("bad inputs"))?,
                outputs: a
                    .get("outputs")
                    .and_then(parse_io)
                    .ok_or_else(|| anyhow!("bad outputs"))?,
            };
            specs.insert((spec.config.clone(), spec.kind.clone()), spec);
        }
        Ok(Self { rt, dir: dir.into(), specs, cache: RefCell::new(BTreeMap::new()) })
    }

    pub fn spec(&self, config: &str, kind: &str) -> Result<&ArtifactSpec> {
        self.specs
            .get(&(config.to_string(), kind.to_string()))
            .ok_or_else(|| anyhow!("no artifact for ({config}, {kind}) in manifest"))
    }

    pub fn has(&self, config: &str, kind: &str) -> bool {
        self.specs.contains_key(&(config.to_string(), kind.to_string()))
    }

    /// Compile (or fetch cached) executable for (config, kind).
    pub fn executable(
        &self,
        config: &str,
        kind: &str,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        let key = (config.to_string(), kind.to_string());
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let spec = self.spec(config, kind)?;
        let path = format!("{}/{}", self.dir, spec.file);
        let exe = std::rc::Rc::new(self.rt.load_hlo_text(&path)?);
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute an artifact; returns the decomposed output tuple.
    /// Accepts owned or borrowed literals (callers cache weight literals).
    pub fn exec<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        config: &str,
        kind: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let spec = self.spec(config, kind)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "({config}, {kind}): expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.executable(config, kind)?;
        let result = exe.execute::<L>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Validate that a Rust-side config matches the manifest shapes.
    pub fn check_config(&self, cfg: &ModelConfig) -> Result<()> {
        let spec = self.spec(cfg.name, "dense_nll")?;
        let want = cfg.param_shapes();
        for ((name, shape), (mname, mshape, _)) in want.iter().zip(&spec.inputs) {
            if name != mname || shape != mshape {
                bail!(
                    "config {} drifted from manifest: {name}{shape:?} vs {mname}{mshape:?} — \
                     re-run `make artifacts`",
                    cfg.name
                );
            }
        }
        Ok(())
    }

    /// Weights as input literals (canonical order).
    pub fn weight_literals(&self, w: &Weights) -> Result<Vec<xla::Literal>> {
        w.tensors.iter().map(|t| lit_f32(&t.data, &t.shape)).collect()
    }
}

/// Convert an f32 output literal back to a flat vec + shape.
pub fn tensor_of(lit: &xla::Literal) -> Result<(Vec<f32>, Vec<usize>)> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    Ok((lit.to_vec::<f32>()?, dims))
}
