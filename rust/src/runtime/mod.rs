//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Python/JAX runs only at build time (`make artifacts`); this module owns
//! request-path execution. Interchange is HLO *text*: jax >= 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects,
//! while the text parser reassigns ids cleanly (see DESIGN.md).

pub mod engine;
pub mod trainer;

use anyhow::Result;

pub use engine::Engine;

/// Thin wrapper over a PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    /// Platform name, e.g. "cpu".
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it into an executable.
    pub fn load_hlo_text(&self, path: &str) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Access the raw client (for XlaBuilder-constructed computations).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

/// Build an f32 literal from flat data + shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal from flat data + shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Scalar f32 literal.
pub fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}
