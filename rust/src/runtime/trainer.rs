//! Training loop: drive the AOT `train_step` artifact from Rust.
//!
//! The whole step (fwd + bwd + Adam) is one XLA computation; Rust owns the
//! schedule (linear warmup → cosine decay), data order, logging, and
//! checkpointing. This is the "train a small transformer and log the loss
//! curve" leg of the end-to-end validation (EXPERIMENTS.md §E2E).

use anyhow::Result;

use super::engine::{tensor_of, Engine};
use super::{lit_f32, lit_i32, lit_scalar};
use crate::data::{Batcher, DataBundle};
use crate::model::{Tensor, Weights};
use crate::util::Timer;

/// Training hyperparameters (Adam moments/clipping live inside the artifact).
pub struct TrainOpts {
    pub steps: usize,
    pub base_lr: f64,
    pub warmup: usize,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self { steps: 400, base_lr: 3e-3, warmup: 20, log_every: 20, seed: 0 }
    }
}

/// Cosine schedule with linear warmup.
pub fn lr_at(opts: &TrainOpts, step: usize) -> f64 {
    if step < opts.warmup {
        return opts.base_lr * (step + 1) as f64 / opts.warmup as f64;
    }
    let t = (step - opts.warmup) as f64 / (opts.steps - opts.warmup).max(1) as f64;
    opts.base_lr * 0.5 * (1.0 + (std::f64::consts::PI * t).cos()).max(0.02)
}

/// Result of a training run.
pub struct TrainLog {
    pub losses: Vec<(usize, f64)>,
    pub final_weights: Weights,
    pub tokens_per_sec: f64,
}

/// Train `weights` in place on the wiki2s stream of `data`.
pub fn train(
    engine: &Engine,
    mut weights: Weights,
    data: &DataBundle,
    opts: &TrainOpts,
) -> Result<TrainLog> {
    let cfg = weights.config;
    engine.check_config(&cfg)?;
    let stream = &data.domain(crate::data::synlang::Domain::Wiki2s).train;
    let mut batcher = Batcher::new(stream, cfg.batch, cfg.seq, opts.seed ^ 0xBA7C4);

    // adam state starts at zero
    let mut m: Vec<Tensor> = weights.tensors.iter().map(|t| Tensor::zeros(t.shape.clone())).collect();
    let mut v: Vec<Tensor> = weights.tensors.iter().map(|t| Tensor::zeros(t.shape.clone())).collect();

    let mut losses = Vec::new();
    let timer = Timer::start();
    let tokens_per_step = (cfg.batch * cfg.seq) as f64;
    for step in 0..opts.steps {
        let batch = batcher.next_batch();
        let mut inputs = Vec::with_capacity(39);
        for t in &weights.tensors {
            inputs.push(lit_f32(&t.data, &t.shape)?);
        }
        for t in &m {
            inputs.push(lit_f32(&t.data, &t.shape)?);
        }
        for t in &v {
            inputs.push(lit_f32(&t.data, &t.shape)?);
        }
        inputs.push(lit_scalar((step + 1) as f32));
        inputs.push(lit_scalar(lr_at(opts, step) as f32));
        inputs.push(lit_i32(&batch, &[cfg.batch, cfg.seq])?);

        let outs = engine.exec(cfg.name, "train_step", &inputs)?;
        let loss = outs[0].to_vec::<f32>()?[0] as f64;
        let n = weights.tensors.len();
        for i in 0..n {
            weights.tensors[i].data = tensor_of(&outs[1 + i])?.0;
            m[i].data = tensor_of(&outs[1 + n + i])?.0;
            v[i].data = tensor_of(&outs[1 + 2 * n + i])?.0;
        }
        // the tensors just changed in place: any GEMM panels packed from a
        // previous step's weights (e.g. an eval forward mid-training) are
        // stale now
        weights.reset_packs();
        if step % opts.log_every == 0 || step + 1 == opts.steps {
            losses.push((step, loss));
        }
        if !loss.is_finite() {
            anyhow::bail!("loss diverged at step {step}");
        }
    }
    let secs = timer.secs();
    Ok(TrainLog {
        losses,
        final_weights: weights,
        tokens_per_sec: tokens_per_step * opts.steps as f64 / secs.max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_shape() {
        let opts = TrainOpts { steps: 100, base_lr: 1e-2, warmup: 10, ..Default::default() };
        assert!(lr_at(&opts, 0) < lr_at(&opts, 9)); // warmup rising
        assert!((lr_at(&opts, 10) - 1e-2).abs() < 1e-3); // peak after warmup
        assert!(lr_at(&opts, 99) < lr_at(&opts, 50)); // decaying
        assert!(lr_at(&opts, 99) > 0.0);
    }
}
