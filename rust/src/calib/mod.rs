//! Calibration: per-projection input statistics over calibration batches.
//!
//! Streams [batch, seq] token windows through the AOT `calib` artifact
//! (whose Gram products run in the Pallas `gram_accum` kernel) and
//! re-accumulates in f64 — the paper keeps the whitening matrix S in FP64.
//! Also collects |x| means (ASVD scaling) and, via the `fisher` artifact,
//! row-aggregated squared gradients (FWSVD weighting).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::data::synlang::Domain;
use crate::data::{Batcher, DataBundle};
use crate::model::{Weights, COMPRESSIBLE};
use crate::runtime::engine::{tensor_of, Engine};
use crate::runtime::lit_i32;
use crate::tensor::MatF;
use crate::util::parallel::{self, parallel_map};
use crate::util::profile::{self, Stage};

/// Where each compressible type reads its input statistics from.
pub fn gram_slot(typ: &str) -> usize {
    match typ {
        "wq" | "wk" | "wv" => 0, // g_attn
        "wo" => 1,               // g_o
        "w_gate" | "w_up" => 2,  // g_mlp
        "w_down" => 3,           // g_down
        _ => panic!("not compressible: {typ}"),
    }
}

/// Accumulated calibration statistics for one model.
pub struct CalibStats {
    /// grams[slot][layer]: mean X^T X (f64), slot as in `gram_slot`
    pub grams: Vec<Vec<MatF>>,
    /// absmean[slot][layer][dim]: mean |x_dim|
    pub absmean: Vec<Vec<Vec<f64>>>,
    /// fisher[type][layer][row]: sum of grad^2 over the output axis
    pub fisher: BTreeMap<String, Vec<Vec<f64>>>,
    /// tokens accumulated
    pub tokens: usize,
}

/// Options for a calibration run.
pub struct CalibOpts {
    pub domain: Domain,
    pub batches: usize,
    pub seed: u64,
    /// also run the fisher artifact (needed by FWSVD only)
    pub fisher: bool,
}

impl Default for CalibOpts {
    fn default() -> Self {
        Self { domain: Domain::Wiki2s, batches: 16, seed: 13, fisher: false }
    }
}

/// Run calibration for `weights` on the chosen domain stream.
pub fn run(
    engine: &Engine,
    weights: &Weights,
    data: &DataBundle,
    opts: &CalibOpts,
) -> Result<CalibStats> {
    let _t = profile::ScopedTimer::new(Stage::Calib);
    let cfg = weights.config;
    let stream = &data.domain(opts.domain).train;
    let mut batcher = Batcher::new(stream, cfg.batch, cfg.seq, opts.seed);

    let slot_dim = [cfg.d, cfg.d, cfg.d, cfg.dff];
    let mut grams: Vec<Vec<MatF>> = slot_dim
        .iter()
        .map(|&d| (0..cfg.layers).map(|_| MatF::zeros(d, d)).collect())
        .collect();
    let mut absmean: Vec<Vec<Vec<f64>>> = slot_dim
        .iter()
        .map(|&d| vec![vec![0.0; d]; cfg.layers])
        .collect();
    let mut fisher: BTreeMap<String, Vec<Vec<f64>>> = BTreeMap::new();
    if opts.fisher {
        for t in COMPRESSIBLE {
            let (d1, _) = cfg.matrix_dims(t);
            fisher.insert(t.to_string(), vec![vec![0.0; d1]; cfg.layers]);
        }
    }

    let mut tokens = 0usize;
    for _ in 0..opts.batches {
        let batch = batcher.next_batch();
        // Literal lacks Clone in the crate's public API, so rebuild the
        // weight literals per batch (cheap relative to the forward pass).
        let mut inputs = engine.weight_literals(weights)?;
        inputs.push(lit_i32(&batch, &[cfg.batch, cfg.seq])?);
        let outs = engine.exec(cfg.name, "calib", &inputs)?;
        // outputs: g_attn, g_o, g_mlp, g_down, a_attn, a_o, a_mlp, a_down
        for slot in 0..4 {
            let (gdata, gshape) = tensor_of(&outs[slot])?;
            let d = gshape[1];
            for l in 0..cfg.layers {
                let off = l * d * d;
                let g = &mut grams[slot][l];
                for i in 0..d * d {
                    g.data[i] += gdata[off + i] as f64;
                }
            }
            let (adata, _) = tensor_of(&outs[4 + slot])?;
            for l in 0..cfg.layers {
                for i in 0..d {
                    absmean[slot][l][i] += adata[l * d + i] as f64;
                }
            }
        }
        if opts.fisher {
            let mut finputs = engine.weight_literals(weights)?;
            finputs.push(lit_i32(&batch, &[cfg.batch, cfg.seq])?);
            let fouts = engine.exec(cfg.name, "fisher", &finputs)?;
            for (ti, t) in COMPRESSIBLE.iter().enumerate() {
                let (fdata, fshape) = tensor_of(&fouts[ti])?;
                let d1 = fshape[1];
                let rows = fisher.get_mut(*t).unwrap();
                for l in 0..cfg.layers {
                    for i in 0..d1 {
                        rows[l][i] += fdata[l * d1 + i] as f64;
                    }
                }
            }
        }
        tokens += cfg.batch * cfg.seq;
    }

    // normalize to per-token means (grams stay as means of x xᵀ)
    normalize(&mut grams, &mut absmean, cfg.layers, tokens);
    Ok(CalibStats { grams, absmean, fisher, tokens })
}

/// Normalize raw per-batch sums to per-token means. Shared by the PJRT
/// and reference calibration paths — the two must stay numerically
/// identical for `run_reference` to remain the artifact-free twin of
/// [`run`].
fn normalize(
    grams: &mut [Vec<MatF>],
    absmean: &mut [Vec<Vec<f64>>],
    layers: usize,
    tokens: usize,
) {
    let scale = 1.0 / tokens.max(1) as f64;
    for slot in 0..4 {
        for l in 0..layers {
            grams[slot][l].scale(scale);
            for v in &mut absmean[slot][l] {
                *v *= scale;
            }
        }
    }
}

/// Pure-Rust calibration via the instrumented reference forward
/// (`model::fwd::accumulate_calib`) — the artifact-free twin of [`run`]:
/// same slots, same per-token normalization, no PJRT or `artifacts/`
/// required. Fisher rows are artifact-only (the backward pass lives in the
/// AOT `fisher` artifact), so `opts.fisher` is rejected here.
pub fn run_reference(
    weights: &Weights,
    data: &DataBundle,
    opts: &CalibOpts,
) -> Result<CalibStats> {
    run_reference_with(weights.config, data, opts, |batch, part| {
        crate::model::fwd::accumulate_calib(
            weights,
            batch,
            weights.config.batch,
            weights.config.seq,
            part,
        )
    })
}

/// [`run_reference`] over a compressed model: the instrumented forward
/// consumes each factored site's (B, C) directly via the `Linear` operator
/// (`model::fwd::accumulate_calib_model`), so compensated recalibration
/// observes the compressed network without ever reconstructing dense
/// weights.
pub fn run_reference_model(
    model: &crate::model::lowrank::CompressedModel,
    data: &DataBundle,
    opts: &CalibOpts,
) -> Result<CalibStats> {
    run_reference_with(model.config(), data, opts, |batch, part| {
        crate::model::fwd::accumulate_calib_model(
            model,
            batch,
            model.config().batch,
            model.config().seq,
            part,
        )
    })
}

/// Shared body of the reference calibration paths, parameterized by the
/// per-batch forward (dense weights or a compressed model).
fn run_reference_with(
    cfg: crate::model::ModelConfig,
    data: &DataBundle,
    opts: &CalibOpts,
    forward: impl Fn(&[i32], &mut crate::model::fwd::CalibSums) + Sync,
) -> Result<CalibStats> {
    let _t = profile::ScopedTimer::new(Stage::Calib);
    anyhow::ensure!(
        !opts.fisher,
        "fisher statistics need the AOT fisher artifact; use the PJRT calibration path"
    );
    let stream = &data.domain(opts.domain).train;
    let mut batcher = Batcher::new(stream, cfg.batch, cfg.seq, opts.seed);
    // Batches are drawn up front (the batcher is stateful, so draw order
    // fixes their contents), then forwarded in parallel. One wave of
    // `threads()` per-batch partials at a time bounds peak memory; partials
    // merge in batch order, so the statistics are bit-identical for any
    // thread count (though grouped differently than a single running sum).
    let batches: Vec<Vec<i32>> = (0..opts.batches).map(|_| batcher.next_batch()).collect();
    let mut sums = crate::model::fwd::CalibSums::new(&cfg);
    let wave = parallel::threads().max(1);
    for chunk in batches.chunks(wave) {
        let partials = parallel_map(chunk.to_vec(), |batch| {
            let mut part = crate::model::fwd::CalibSums::new(&cfg);
            forward(&batch, &mut part);
            part
        });
        for p in &partials {
            sums.merge(p);
        }
    }
    let tokens = sums.tokens;
    let mut grams = sums.grams;
    let mut absmean = sums.absmean;
    normalize(&mut grams, &mut absmean, cfg.layers, tokens);
    Ok(CalibStats { grams, absmean, fisher: BTreeMap::new(), tokens })
}

impl CalibStats {
    /// Synthetic statistics for unit tests / offline experiments: random
    /// anisotropic PSD grams, positive absmeans, uniform fisher rows.
    pub fn synthetic(cfg: &crate::model::ModelConfig, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let slot_dim = [cfg.d, cfg.d, cfg.d, cfg.dff];
        let mut grams = Vec::new();
        let mut absmean = Vec::new();
        for &d in &slot_dim {
            let mut per_layer = Vec::new();
            let mut per_layer_abs = Vec::new();
            for _ in 0..cfg.layers {
                // anisotropic: X rows scaled by 1/(1+j)
                let samples = d + 8;
                let mut x = MatF::zeros(samples, d);
                for r in 0..samples {
                    for c in 0..d {
                        *x.at_mut(r, c) = rng.normal() / (1.0 + c as f64 * 0.05);
                    }
                }
                let mut g = x.t_matmul(&x);
                g.scale(1.0 / samples as f64);
                per_layer.push(g);
                per_layer_abs.push((0..d).map(|c| 0.8 / (1.0 + c as f64 * 0.05)).collect());
            }
            grams.push(per_layer);
            absmean.push(per_layer_abs);
        }
        let mut fisher = BTreeMap::new();
        for t in COMPRESSIBLE {
            let (d1, _) = cfg.matrix_dims(t);
            fisher.insert(
                t.to_string(),
                (0..cfg.layers)
                    .map(|_| (0..d1).map(|_| rng.uniform() + 0.1).collect())
                    .collect(),
            );
        }
        Self { grams, absmean, fisher, tokens: 1024 }
    }

    /// Mean input Gram for (type, layer).
    pub fn gram(&self, typ: &str, layer: usize) -> &MatF {
        &self.grams[gram_slot(typ)][layer]
    }

    /// Mean |x| per input dim for (type, layer).
    pub fn absmean(&self, typ: &str, layer: usize) -> &[f64] {
        &self.absmean[gram_slot(typ)][layer]
    }

    /// Fisher rows for (type, layer), if collected.
    pub fn fisher_rows(&self, typ: &str, layer: usize) -> Option<&[f64]> {
        self.fisher.get(typ).map(|v| v[layer].as_slice())
    }
}
