//! Data pipeline: corpora, tokenization, splits, and batch iterators.
//!
//! A `DataBundle` owns the three domain corpora (train/val/test token
//! streams through a shared BPE tokenizer) plus the lexicon behind the
//! zero-shot suites. Everything is deterministic in (seed, vocab size).

pub mod synlang;
pub mod tasks;

use synlang::{Domain, Generator, Lexicon};

use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// Token streams for one domain.
pub struct DomainData {
    pub domain: Domain,
    pub train: Vec<u32>,
    pub val: Vec<u32>,
    pub test: Vec<u32>,
}

/// The full data substrate.
pub struct DataBundle {
    pub lexicon: Lexicon,
    pub tokenizer: Tokenizer,
    pub domains: Vec<DomainData>,
}

impl DataBundle {
    /// Build corpora for all three domains, train the tokenizer on the
    /// wiki2s training text (the paper calibrates on WikiText-2), and
    /// tokenize everything. `scale` multiplies corpus sizes (1 = default).
    pub fn build(vocab_size: usize, seed: u64, scale: f64) -> Self {
        let lexicon = Lexicon::new();
        let sizes = |d: Domain| match d {
            Domain::Wiki2s => (1_200_000.0 * scale, 60_000.0 * scale),
            Domain::Ptbs => (300_000.0 * scale, 50_000.0 * scale),
            Domain::C4s => (600_000.0 * scale, 60_000.0 * scale),
        };
        let mut texts = Vec::new();
        for (i, d) in [Domain::Wiki2s, Domain::Ptbs, Domain::C4s].iter().enumerate() {
            let (train_sz, eval_sz) = sizes(*d);
            let mut g = Generator::new(&lexicon, *d, seed.wrapping_add(i as u64 * 77));
            let train = g.corpus(train_sz as usize);
            let val = g.corpus(eval_sz as usize);
            let test = g.corpus(eval_sz as usize);
            texts.push((*d, train, val, test));
        }
        let tokenizer = Tokenizer::train(&texts[0].1, vocab_size);
        let domains = texts
            .into_iter()
            .map(|(domain, train, val, test)| DomainData {
                domain,
                train: tokenizer.encode(&train),
                val: tokenizer.encode(&val),
                test: tokenizer.encode(&test),
            })
            .collect();
        Self { lexicon, tokenizer, domains }
    }

    pub fn domain(&self, d: Domain) -> &DomainData {
        self.domains.iter().find(|x| x.domain == d).unwrap()
    }

    /// Build with a disk cache under `runs/cache` (corpus generation + BPE
    /// training are deterministic in the key, so cached results are exact).
    pub fn build_cached(vocab_size: usize, seed: u64, scale: f64) -> Self {
        let dir = format!("runs/cache/v{vocab_size}_s{seed}_x{}", (scale * 1000.0) as u64);
        let tok_path = format!("{dir}/tokenizer.json");
        if std::path::Path::new(&tok_path).exists() {
            if let Some(b) = Self::load_cache(&dir) {
                return b;
            }
        }
        let bundle = Self::build(vocab_size, seed, scale);
        let _ = std::fs::create_dir_all(&dir);
        let _ = bundle.tokenizer.save(&tok_path);
        for d in &bundle.domains {
            for (split, stream) in
                [("train", &d.train), ("val", &d.val), ("test", &d.test)]
            {
                let bytes: Vec<u8> =
                    stream.iter().flat_map(|&t| t.to_le_bytes()).collect();
                let _ = std::fs::write(
                    format!("{dir}/{}_{split}.bin", d.domain.name()),
                    bytes,
                );
            }
        }
        bundle
    }

    fn load_cache(dir: &str) -> Option<Self> {
        let tokenizer = Tokenizer::load(&format!("{dir}/tokenizer.json")).ok()?;
        let read = |name: &str| -> Option<Vec<u32>> {
            let raw = std::fs::read(format!("{dir}/{name}.bin")).ok()?;
            Some(
                raw.chunks_exact(4)
                    .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                    .collect(),
            )
        };
        let mut domains = Vec::new();
        for d in [Domain::Wiki2s, Domain::Ptbs, Domain::C4s] {
            domains.push(DomainData {
                domain: d,
                train: read(&format!("{}_train", d.name()))?,
                val: read(&format!("{}_val", d.name()))?,
                test: read(&format!("{}_test", d.name()))?,
            });
        }
        Some(Self { lexicon: Lexicon::new(), tokenizer, domains })
    }
}

/// Deterministic [batch, seq] sampler over a token stream.
pub struct Batcher<'a> {
    stream: &'a [u32],
    batch: usize,
    seq: usize,
    rng: Rng,
}

impl<'a> Batcher<'a> {
    pub fn new(stream: &'a [u32], batch: usize, seq: usize, seed: u64) -> Self {
        assert!(stream.len() > seq + 1, "stream too short for seq {seq}");
        Self { stream, batch, seq, rng: Rng::new(seed) }
    }

    /// Random-offset batch as i32 token ids (XLA input dtype), row-major.
    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let start = self.rng.below(self.stream.len() - self.seq);
            out.extend(self.stream[start..start + self.seq].iter().map(|&t| t as i32));
        }
        out
    }

    /// Sequential coverage batches for PPL eval: non-overlapping windows.
    pub fn eval_batches(stream: &[u32], batch: usize, seq: usize, max_batches: usize) -> Vec<Vec<i32>> {
        let mut out = Vec::new();
        let mut pos = 0;
        'outer: for _ in 0..max_batches {
            let mut b = Vec::with_capacity(batch * seq);
            for _ in 0..batch {
                if pos + seq >= stream.len() {
                    break 'outer;
                }
                b.extend(stream[pos..pos + seq].iter().map(|&t| t as i32));
                pos += seq;
            }
            out.push(b);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_bundle() -> DataBundle {
        DataBundle::build(128, 42, 0.02)
    }

    #[test]
    fn bundle_builds_all_domains() {
        let b = small_bundle();
        assert_eq!(b.domains.len(), 3);
        for d in &b.domains {
            assert!(d.train.len() > 500, "{:?} {}", d.domain, d.train.len());
            assert!(d.val.len() > 100, "{:?} {}", d.domain, d.val.len());
            assert!(d.test.len() > 100);
        }
    }

    #[test]
    fn token_ids_in_vocab_range() {
        let b = small_bundle();
        let v = b.tokenizer.vocab_size() as u32;
        for d in &b.domains {
            assert!(d.train.iter().all(|&t| t < v));
        }
    }

    #[test]
    fn batcher_shapes_and_determinism() {
        let b = small_bundle();
        let stream = &b.domain(Domain::Wiki2s).train;
        let mut b1 = Batcher::new(stream, 4, 32, 7);
        let mut b2 = Batcher::new(stream, 4, 32, 7);
        let x1 = b1.next_batch();
        let x2 = b2.next_batch();
        assert_eq!(x1.len(), 4 * 32);
        assert_eq!(x1, x2);
        assert_ne!(b1.next_batch(), x1);
    }

    #[test]
    fn eval_batches_are_disjoint_and_cover() {
        let b = small_bundle();
        let stream = &b.domain(Domain::Ptbs).val;
        let batches = Batcher::eval_batches(stream, 2, 16, 8);
        assert!(!batches.is_empty());
        // windows are sequential; first token of batch0/row0 is stream[0]
        assert_eq!(batches[0][0], stream[0] as i32);
        assert_eq!(batches[0][16], stream[16] as i32);
    }

    #[test]
    fn same_seed_same_bundle() {
        let a = DataBundle::build(96, 9, 0.005);
        let b = DataBundle::build(96, 9, 0.005);
        assert_eq!(a.domain(Domain::Wiki2s).train, b.domain(Domain::Wiki2s).train);
    }
}
