//! "synlang" — a synthetic probabilistic language with learnable structure.
//!
//! Stands in for WikiText-2 / PTB / C4 (unavailable offline; see DESIGN.md
//! substitution table). The grammar embeds exactly the regularities the
//! seven zero-shot suites probe, so a trained LM's accuracy on them degrades
//! gracefully under compression the way LLaMA's does on lm-eval:
//!
//!  - noun-class agreement, local and across a distractor (ARC-e / WinoG.)
//!  - verb-chain Markov preferences (HellaSwag)
//!  - verb-tool affinities (PIQA)
//!  - noun-object facts that must be memorized (OpenbookQA)
//!  - modular digit arithmetic (MathQA)
//!
//! Three domain parameterizations re-create the paper's dataset axes:
//! `wiki2s` (base), `ptbs` (shorter, peakier), `c4s` (topic-shifted,
//! noisier) — giving an out-of-distribution axis for Table 8.

use crate::util::rng::Rng;

// Lexicon scale matters: compression hurts LLMs through the *long tail*
// (rare tokens ride low-energy weight directions that truncation kills).
// A large zipf-distributed lexicon with hundreds of memorizable facts makes
// tiny models use enough of their capacity that SVD truncation measurably
// degrades PPL — see EXPERIMENTS.md §Calibration-of-the-substrate.
pub const N_NOUNS: usize = 300;
pub const N_VERBS: usize = 96;
pub const N_OBJECTS: usize = 160;
pub const N_TOOLS: usize = 64;

const CONS: [&str; 10] = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r"];
const VOW: [&str; 5] = ["a", "e", "i", "o", "u"];
const DIGITS: [&str; 10] = [
    "zefo", "wuno", "tvo", "tris", "kfor", "fivo", "sixa", "sevi", "okto", "nino",
];

/// Deterministic two-syllable surface form for a word id within a family.
fn surface(family: u64, id: usize) -> String {
    let mut r = Rng::new(0x5EED_0000 + family * 1000 + id as u64);
    let mut s = String::new();
    for _ in 0..2 {
        s.push_str(CONS[r.below(CONS.len())]);
        s.push_str(VOW[r.below(VOW.len())]);
    }
    s
}

/// A family of surfaces with collisions resolved (50^2 two-syllable forms
/// cannot fit 300 nouns collision-free; extend colliding words by an extra
/// deterministic syllable until unique).
fn family(tag: u64, n: usize) -> Vec<String> {
    let mut seen = std::collections::BTreeSet::new();
    (0..n)
        .map(|i| {
            let mut s = surface(tag, i);
            let mut salt = 0u64;
            while !seen.insert(s.clone()) {
                let mut r = Rng::new(0xD15A_0000 + tag * 7919 + i as u64 * 31 + salt);
                s.push_str(CONS[r.below(CONS.len())]);
                s.push_str(VOW[r.below(VOW.len())]);
                salt += 1;
            }
            s
        })
        .collect()
}

/// The fixed lexicon + relational structure shared by every domain.
pub struct Lexicon {
    pub nouns: Vec<String>,
    pub noun_class: Vec<usize>, // 0 or 1; controls verb agreement suffix
    pub verbs: Vec<String>,     // stem; agreement adds "ra"(0) / "ti"(1)
    pub objects: Vec<String>,
    pub tools: Vec<String>,
    pub likes: Vec<usize>,      // noun -> object (facts)
    pub verb_tool: Vec<usize>,  // verb -> tool (affinities)
    pub verb_next: Vec<usize>,  // verb -> preferred successor verb (chains)
}

impl Lexicon {
    pub fn new() -> Self {
        let mut r = Rng::new(0xC0FFEE);
        let nouns = family(1, N_NOUNS);
        let verbs = family(2, N_VERBS);
        let objects = family(3, N_OBJECTS);
        let tools = family(4, N_TOOLS);
        Self {
            noun_class: (0..N_NOUNS).map(|_| r.below(2)).collect(),
            likes: (0..N_NOUNS).map(|_| r.below(N_OBJECTS)).collect(),
            verb_tool: (0..N_VERBS).map(|_| r.below(N_TOOLS)).collect(),
            verb_next: (0..N_VERBS).map(|_| r.below(N_VERBS)).collect(),
            nouns,
            verbs,
            objects,
            tools,
        }
    }

    /// Agreement-inflected verb form for a noun class.
    pub fn verb_form(&self, verb: usize, class: usize) -> String {
        format!("{}{}", self.verbs[verb], if class == 0 { "ra" } else { "ti" })
    }

    pub fn digit(&self, d: usize) -> &'static str {
        DIGITS[d % 10]
    }
}

impl Default for Lexicon {
    fn default() -> Self {
        Self::new()
    }
}

/// Domain parameterization (the WikiText-2 / PTB / C4 analogs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    Wiki2s,
    Ptbs,
    C4s,
}

impl Domain {
    pub fn parse(s: &str) -> Option<Domain> {
        match s {
            "wiki2s" | "wikitext2" => Some(Domain::Wiki2s),
            "ptbs" | "ptb" => Some(Domain::Ptbs),
            "c4s" | "c4" => Some(Domain::C4s),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Domain::Wiki2s => "wiki2s",
            Domain::Ptbs => "ptbs",
            Domain::C4s => "c4s",
        }
    }

    /// (template weights [svo, agree, fact, chain, math, tool],
    ///  zipf exponent, noun offset, noise prob)
    fn params(self) -> ([f64; 6], f64, usize, f64) {
        match self {
            Domain::Wiki2s => ([4.0, 2.0, 2.0, 2.0, 1.0, 1.5], 1.0, 0, 0.00),
            Domain::Ptbs => ([5.0, 1.5, 1.5, 1.0, 0.5, 1.0], 1.4, 0, 0.00),
            // topic shift: nouns drawn from the upper half of the lexicon,
            // flatter distribution, occasional random-word noise
            Domain::C4s => ([3.0, 2.0, 2.0, 3.0, 1.5, 2.0], 0.6, N_NOUNS / 2, 0.03),
        }
    }
}

/// Sentence generator for one domain.
pub struct Generator<'a> {
    pub lex: &'a Lexicon,
    pub domain: Domain,
    rng: Rng,
    zipf: Vec<f64>,
}

impl<'a> Generator<'a> {
    pub fn new(lex: &'a Lexicon, domain: Domain, seed: u64) -> Self {
        let (_, zipf_exp, offset, _) = domain.params();
        // zipf weights over nouns with a domain-specific rotation
        let zipf = (0..N_NOUNS)
            .map(|i| 1.0 / ((((i + offset) % N_NOUNS) + 1) as f64).powf(zipf_exp))
            .collect();
        Self { lex, domain, rng: Rng::new(seed), zipf }
    }

    fn noun(&mut self) -> usize {
        let w = self.zipf.clone();
        self.rng.categorical(&w)
    }

    /// One sentence of the domain's mixture.
    pub fn sentence(&mut self) -> String {
        let (weights, _, _, noise) = self.domain.params();
        if self.rng.uniform() < noise {
            // C4-style junk: random word soup
            let n = 3 + self.rng.below(4);
            let mut parts = Vec::new();
            for _ in 0..n {
                parts.push(surface(9, self.rng.below(50)));
            }
            return parts.join(" ");
        }
        let lex = self.lex;
        match self.rng.categorical(&weights) {
            0 => {
                // SVO with local agreement
                let n = self.noun();
                let v = self.rng.below(N_VERBS);
                let o = self.rng.below(N_OBJECTS);
                format!(
                    "the {} {} the {}",
                    lex.nouns[n],
                    lex.verb_form(v, lex.noun_class[n]),
                    lex.objects[o]
                )
            }
            1 => {
                // long-range agreement across a distractor of the other class
                let n = self.noun();
                let other: Vec<usize> = (0..N_NOUNS)
                    .filter(|&m| lex.noun_class[m] != lex.noun_class[n])
                    .collect();
                let d = other[self.rng.below(other.len())];
                let v = self.rng.below(N_VERBS);
                format!(
                    "the {} near the {} {}",
                    lex.nouns[n],
                    lex.nouns[d],
                    lex.verb_form(v, lex.noun_class[n])
                )
            }
            2 => {
                // memorizable fact
                let n = self.noun();
                format!("the {} likes the {}", lex.nouns[n], lex.objects[lex.likes[n]])
            }
            3 => {
                // verb chain following verb_next with prob .8
                let mut v = self.rng.below(N_VERBS);
                let mut parts = vec![format!("then {}", lex.verbs[v])];
                for _ in 0..2 + self.rng.below(2) {
                    v = if self.rng.uniform() < 0.8 {
                        lex.verb_next[v]
                    } else {
                        self.rng.below(N_VERBS)
                    };
                    parts.push(format!("then {}", lex.verbs[v]));
                }
                parts.join(" ")
            }
            4 => {
                // modular arithmetic
                let a = self.rng.below(10);
                let b = self.rng.below(10);
                if self.rng.uniform() < 0.5 {
                    format!(
                        "{} plus {} eq {}",
                        lex.digit(a),
                        lex.digit(b),
                        lex.digit((a + b) % 10)
                    )
                } else {
                    format!(
                        "{} minus {} eq {}",
                        lex.digit(a),
                        lex.digit(b),
                        lex.digit((10 + a - b) % 10)
                    )
                }
            }
            _ => {
                // verb-tool affinity
                let v = self.rng.below(N_VERBS);
                format!("{} with the {}", lex.verbs[v], lex.tools[lex.verb_tool[v]])
            }
        }
    }

    /// A corpus of roughly `target_chars` characters.
    pub fn corpus(&mut self, target_chars: usize) -> String {
        let mut out = String::with_capacity(target_chars + 64);
        while out.len() < target_chars {
            if !out.is_empty() {
                out.push_str(" ; ");
            }
            out.push_str(&self.sentence());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexicon_is_deterministic() {
        let a = Lexicon::new();
        let b = Lexicon::new();
        assert_eq!(a.nouns, b.nouns);
        assert_eq!(a.likes, b.likes);
    }

    #[test]
    fn surfaces_are_distinct_within_family() {
        let lex = Lexicon::new();
        for fam in [&lex.nouns, &lex.verbs, &lex.objects, &lex.tools] {
            let seen: std::collections::BTreeSet<_> = fam.iter().collect();
            assert_eq!(seen.len(), fam.len(), "collision in family");
        }
    }

    #[test]
    fn corpus_reaches_target_and_is_ascii() {
        let lex = Lexicon::new();
        let mut g = Generator::new(&lex, Domain::Wiki2s, 1);
        let c = g.corpus(10_000);
        assert!(c.len() >= 10_000);
        assert!(c.is_ascii());
    }

    #[test]
    fn agreement_holds_in_svo_sentences() {
        let lex = Lexicon::new();
        let mut g = Generator::new(&lex, Domain::Wiki2s, 2);
        let mut checked = 0;
        for _ in 0..200 {
            let s = g.sentence();
            let words: Vec<&str> = s.split(' ').collect();
            if words.len() == 5 && words[0] == "the" && words[3] == "the" && words[2] != "likes" {
                let noun_idx = lex.nouns.iter().position(|n| n == words[1]);
                if let Some(ni) = noun_idx {
                    let suffix = if lex.noun_class[ni] == 0 { "ra" } else { "ti" };
                    assert!(words[2].ends_with(suffix), "{s}");
                    checked += 1;
                }
            }
        }
        assert!(checked > 10, "not enough SVO sentences sampled");
    }

    #[test]
    fn domains_differ() {
        let lex = Lexicon::new();
        let a = Generator::new(&lex, Domain::Wiki2s, 3).corpus(5000);
        let b = Generator::new(&lex, Domain::C4s, 3).corpus(5000);
        assert_ne!(a, b);
    }

    #[test]
    fn math_sentences_are_consistent() {
        let lex = Lexicon::new();
        let mut g = Generator::new(&lex, Domain::Wiki2s, 4);
        let mut checked = 0;
        for _ in 0..500 {
            let s = g.sentence();
            let w: Vec<&str> = s.split(' ').collect();
            if w.len() == 5 && w[1] == "plus" {
                let d = |x: &str| DIGITS.iter().position(|&d| d == x).unwrap();
                assert_eq!((d(w[0]) + d(w[2])) % 10, d(w[4]), "{s}");
                checked += 1;
            }
        }
        assert!(checked > 5);
    }
}
