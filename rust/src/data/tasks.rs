//! Zero-shot multiple-choice suites over synlang (lm-eval analogs).
//!
//! Each suite generates items with a prompt and N options, exactly one of
//! which is consistent with the grammar/facts the training corpus teaches.
//! Scoring (eval::tasks) follows LM-Evaluation-Harness: pick the option
//! with the highest length-normalized log-likelihood as a continuation.

use super::synlang::{Lexicon, N_NOUNS, N_OBJECTS, N_TOOLS, N_VERBS};
use crate::util::rng::Rng;

/// One multiple-choice item. `options` are continuations of `prompt`;
/// `answer` indexes the correct one.
#[derive(Clone, Debug)]
pub struct Item {
    pub prompt: String,
    pub options: Vec<String>,
    pub answer: usize,
}

/// The seven suites (paper's zero-shot columns, in table order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    Openbook,  // Openb.  : noun -> liked object facts (4-way)
    ArcEasy,   // ARC_e   : local agreement, weak distractors (4-way)
    Winogrande, // WinoG. : agreement across a distractor noun (2-way)
    Hellaswag, // HellaS. : verb-chain continuation (4-way)
    ArcChallenge, // ARC_c: agreement with hard distractors (4-way)
    Piqa,      // PIQA    : verb-tool affinity (2-way)
    Mathqa,    // MathQA  : modular arithmetic (4-way)
}

pub const ALL_SUITES: [Suite; 7] = [
    Suite::Openbook,
    Suite::ArcEasy,
    Suite::Winogrande,
    Suite::Hellaswag,
    Suite::ArcChallenge,
    Suite::Piqa,
    Suite::Mathqa,
];

impl Suite {
    pub fn name(self) -> &'static str {
        match self {
            Suite::Openbook => "Openb.",
            Suite::ArcEasy => "ARC_e",
            Suite::Winogrande => "WinoG.",
            Suite::Hellaswag => "HellaS.",
            Suite::ArcChallenge => "ARC_c",
            Suite::Piqa => "PIQA",
            Suite::Mathqa => "MathQA",
        }
    }

    pub fn n_options(self) -> usize {
        match self {
            Suite::Winogrande | Suite::Piqa => 2,
            _ => 4,
        }
    }

    /// Generate `n` items with a deterministic seed.
    pub fn items(self, lex: &Lexicon, n: usize, seed: u64) -> Vec<Item> {
        let mut r = Rng::new(seed ^ (self as u64) << 32);
        (0..n).map(|_| self.item(lex, &mut r)).collect()
    }

    fn item(self, lex: &Lexicon, r: &mut Rng) -> Item {
        match self {
            Suite::Openbook => {
                let n = r.below(N_NOUNS);
                let correct = lex.likes[n];
                let mut opts = vec![correct];
                while opts.len() < 4 {
                    let o = r.below(N_OBJECTS);
                    if !opts.contains(&o) {
                        opts.push(o);
                    }
                }
                shuffle_item(
                    format!("the {} likes the", lex.nouns[n]),
                    opts.iter().map(|&o| format!(" {}", lex.objects[o])).collect(),
                    r,
                )
            }
            Suite::ArcEasy => {
                // "the <noun>" -> agreement-correct verb form; distractors are
                // the wrong-class form + two *other* verbs in the wrong class.
                let n = r.below(N_NOUNS);
                let c = lex.noun_class[n];
                let v = r.below(N_VERBS);
                let mut options = vec![format!(" {}", lex.verb_form(v, c))];
                options.push(format!(" {}", lex.verb_form(v, 1 - c)));
                while options.len() < 4 {
                    let v2 = r.below(N_VERBS);
                    let o = format!(" {}", lex.verb_form(v2, 1 - c));
                    if !options.contains(&o) {
                        options.push(o);
                    }
                }
                shuffle_item(format!("the {}", lex.nouns[n]), options, r)
            }
            Suite::Winogrande => {
                // head-noun agreement across an other-class distractor
                let n = r.below(N_NOUNS);
                let c = lex.noun_class[n];
                let other: Vec<usize> = (0..N_NOUNS)
                    .filter(|&m| lex.noun_class[m] != c)
                    .collect();
                let d = other[r.below(other.len())];
                let v = r.below(N_VERBS);
                shuffle_item(
                    format!("the {} near the {}", lex.nouns[n], lex.nouns[d]),
                    vec![
                        format!(" {}", lex.verb_form(v, c)),
                        format!(" {}", lex.verb_form(v, 1 - c)),
                    ],
                    r,
                )
            }
            Suite::Hellaswag => {
                // chain continuation: preferred successor vs 3 non-successors
                let v = r.below(N_VERBS);
                let correct = lex.verb_next[v];
                let mut opts = vec![correct];
                while opts.len() < 4 {
                    let w = r.below(N_VERBS);
                    if w != correct && w != v && !opts.contains(&w) {
                        opts.push(w);
                    }
                }
                shuffle_item(
                    format!("then {} then", lex.verbs[v]),
                    opts.iter().map(|&w| format!(" {}", lex.verbs[w])).collect(),
                    r,
                )
            }
            Suite::ArcChallenge => {
                // hard: distractor noun of the *same* class in between, options
                // are agreement forms of 4 different verbs — model must both
                // resolve agreement and prefer a plausible verb. Options share
                // the correct class, so the cue is distributional, not
                // morphological (harder than ARC_e by construction).
                let n = r.below(N_NOUNS);
                let c = lex.noun_class[n];
                let same: Vec<usize> = (0..N_NOUNS)
                    .filter(|&m| m != n && lex.noun_class[m] == c)
                    .collect();
                let d = same[r.below(same.len())];
                let v = r.below(N_VERBS);
                let mut options = vec![format!(" {}", lex.verb_form(v, c))];
                options.push(format!(" {}", lex.verb_form(v, 1 - c)));
                let v2 = (v + 1 + r.below(N_VERBS - 1)) % N_VERBS;
                options.push(format!(" {}", lex.verb_form(v2, 1 - c)));
                let v3 = (v + 1 + r.below(N_VERBS - 1)) % N_VERBS;
                options.push(format!(" {}x", lex.verbs[v3])); // corrupt form
                shuffle_item(
                    format!("the {} near the {}", lex.nouns[n], lex.nouns[d]),
                    options,
                    r,
                )
            }
            Suite::Piqa => {
                let v = r.below(N_VERBS);
                let correct = lex.verb_tool[v];
                let mut wrong = r.below(N_TOOLS);
                while wrong == correct {
                    wrong = r.below(N_TOOLS);
                }
                shuffle_item(
                    format!("{} with the", lex.verbs[v]),
                    vec![
                        format!(" {}", lex.tools[correct]),
                        format!(" {}", lex.tools[wrong]),
                    ],
                    r,
                )
            }
            Suite::Mathqa => {
                let a = r.below(10);
                let b = r.below(10);
                let correct = (a + b) % 10;
                let mut opts = vec![correct];
                while opts.len() < 4 {
                    let d = r.below(10);
                    if !opts.contains(&d) {
                        opts.push(d);
                    }
                }
                shuffle_item(
                    format!("{} plus {} eq", lex.digit(a), lex.digit(b)),
                    opts.iter().map(|&d| format!(" {}", lex.digit(d))).collect(),
                    r,
                )
            }
        }
    }
}

/// Shuffle options (answer currently at index 0), track the new answer.
fn shuffle_item(prompt: String, mut options: Vec<String>, r: &mut Rng) -> Item {
    let n = options.len();
    let mut order: Vec<usize> = (0..n).collect();
    r.shuffle(&mut order);
    let answer = order.iter().position(|&i| i == 0).unwrap();
    options = order.iter().map(|&i| std::mem::take(&mut options[i])).collect();
    Item { prompt, options, answer }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_suites_generate() {
        let lex = Lexicon::new();
        for suite in ALL_SUITES {
            let items = suite.items(&lex, 50, 7);
            assert_eq!(items.len(), 50);
            for it in &items {
                assert_eq!(it.options.len(), suite.n_options());
                assert!(it.answer < it.options.len());
                assert!(!it.prompt.is_empty());
                // options must be distinct
                let mut o = it.options.clone();
                o.sort();
                o.dedup();
                assert_eq!(o.len(), it.options.len(), "{it:?}");
            }
        }
    }

    #[test]
    fn answers_are_shuffled() {
        let lex = Lexicon::new();
        let items = Suite::Openbook.items(&lex, 100, 3);
        let first_count = items.iter().filter(|i| i.answer == 0).count();
        assert!(first_count > 5 && first_count < 50, "{first_count}");
    }

    #[test]
    fn deterministic_by_seed() {
        let lex = Lexicon::new();
        let a = Suite::Mathqa.items(&lex, 10, 42);
        let b = Suite::Mathqa.items(&lex, 10, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn openbook_answer_is_the_fact() {
        let lex = Lexicon::new();
        for it in Suite::Openbook.items(&lex, 30, 9) {
            let noun = it.prompt.split(' ').nth(1).unwrap();
            let ni = lex.nouns.iter().position(|n| n == noun).unwrap();
            let want = format!(" {}", lex.objects[lex.likes[ni]]);
            assert_eq!(it.options[it.answer], want);
        }
    }

    #[test]
    fn mathqa_answer_is_correct_sum() {
        let lex = Lexicon::new();
        for it in Suite::Mathqa.items(&lex, 30, 11) {
            let w: Vec<&str> = it.prompt.split(' ').collect();
            let d = |x: &str| (0..10).find(|&i| lex.digit(i) == x).unwrap();
            let want = format!(" {}", lex.digit((d(w[0]) + d(w[2])) % 10));
            assert_eq!(it.options[it.answer], want);
        }
    }
}
