//! # D-Rank: layer-wise dynamic rank allocation for LLM compression
//!
//! Reproduction of *"Layer-wise Dynamic Rank for Compressing Large Language
//! Models"* as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L1 (Pallas, build-time)**: fused low-rank matmul, Gram accumulation,
//!   flash attention — `python/compile/kernels/`.
//! - **L2 (JAX, build-time)**: the tinylm transformer family, AOT-lowered to
//!   HLO-text artifacts — `python/compile/model.py` + `aot.py`.
//! - **L3 (this crate, runtime)**: the compression framework (effective
//!   rank, Lagrange allocation, β-rebalancing, six methods), calibration,
//!   evaluation, and a batching serving coordinator over PJRT.
//!
//! Python never runs on the request path; the compressed forward pass with
//! exact dynamic ranks is built at runtime via `XlaBuilder` (`graph`).

pub mod calib;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod graph;
pub mod linalg;
pub mod lora;
pub mod model;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod tokenizer;
pub mod util;
