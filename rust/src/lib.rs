//! # D-Rank: layer-wise dynamic rank allocation for LLM compression
//!
//! Reproduction of *"Layer-wise Dynamic Rank for Compressing Large Language
//! Models"* as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L1 (Pallas, build-time)**: fused low-rank matmul, Gram accumulation,
//!   flash attention — `python/compile/kernels/`.
//! - **L2 (JAX, build-time)**: the tinylm transformer family, AOT-lowered to
//!   HLO-text artifacts — `python/compile/model.py` + `aot.py`.
//! - **L3 (this crate, runtime)**: the compression framework (effective
//!   rank, Lagrange allocation, β-rebalancing, six methods), calibration,
//!   evaluation, and a multi-worker batching serving coordinator.
//!
//! The serving stack is built around the `coordinator::ScoreBackend` seam:
//! `Server::spawn` starts N worker threads over one shared bounded queue,
//! and each worker constructs its own backend inside its thread (PJRT
//! handles are `!Send`). Production workers run the runtime-compiled XLA
//! graph (`graph::CompiledForward`); `coordinator::RefBackend` wraps the
//! pure-Rust reference forward (`model::fwd`) so the coordinator — and its
//! test suite — runs with no `artifacts/` directory and no PJRT at all.
//! The reference forward itself is batched: every projection site resolves
//! to a `model::lowrank::Linear` operator (dense slab or `B`/`C` factor
//! pair), so `RefBackend` serves compressed models on their factors
//! directly — removed parameters are never rematerialized.
//! Batches are assembled per worker with length bucketing, per-request
//! deadlines, and typed `QueueFull`/`Timeout`/`TooLong` rejection; shutdown
//! drains every queued request before the workers exit.
//!
//! Python never runs on the request path; the compressed forward pass with
//! exact dynamic ranks is built at runtime via `XlaBuilder` (`graph`).

pub mod calib;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod graph;
pub mod linalg;
pub mod lora;
pub mod model;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod tokenizer;
pub mod util;
