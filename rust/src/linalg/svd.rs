//! Thin SVD via eigendecomposition of the smaller-side Gram matrix.
//!
//! For A (m×n), eigendecompose AAᵀ (if m<=n) or AᵀA, then recover the
//! other factor by projection. The smaller side here is at most ~768
//! (d or dff), so the Jacobi solve dominates; it runs through the
//! blocked round-robin sweep (`jacobi_eigen_blocked`), which fans each
//! tournament round's disjoint rotations out on the `--threads` pool
//! while staying bit-identical to the serial solver. Accuracy of small
//! singular triplets is limited by the squaring (σ ~ sqrt(eps) floor);
//! the compression pipeline only consumes the *leading* k triplets and
//! the σ² distribution (effective rank), both of which the Gram route
//! computes accurately at f64.

use super::eigen::jacobi_eigen_blocked;
use crate::tensor::MatF;
use crate::util::parallel::parallel_row_bands;
use crate::util::profile::{self, Stage};

/// Thin SVD A = U diag(s) Vᵀ with singular values sorted descending.
pub struct Svd {
    pub u: MatF,       // m × r
    pub s: Vec<f64>,   // r
    pub vt: MatF,      // r × n
}

/// Compute the thin SVD (r = min(m, n)).
pub fn svd(a: &MatF) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let r = m.min(n);
    if m <= n {
        // AAᵀ = U Λ Uᵀ ;  Vᵀ = Σ⁻¹ Uᵀ A
        let g = profile::time(Stage::Gram, || gram_right(a)); // A Aᵀ, m×m
        let e = jacobi_eigen_blocked(&g); // self-times eigen_sweep/eigen_sort
        let s: Vec<f64> = e.values.iter().take(r).map(|&w| w.max(0.0).sqrt()).collect();
        let u = e.vectors; // m×m, columns sorted
        let uta = u.t_matmul(a); // m×n
        let mut vt = MatF::zeros(r, n);
        for i in 0..r {
            let inv = if s[i] > sv_floor(&s) { 1.0 / s[i] } else { 0.0 };
            for j in 0..n {
                *vt.at_mut(i, j) = uta.at(i, j) * inv;
            }
        }
        let mut u_thin = MatF::zeros(m, r);
        for i in 0..m {
            for j in 0..r {
                *u_thin.at_mut(i, j) = u.at(i, j);
            }
        }
        Svd { u: u_thin, s, vt }
    } else {
        // AᵀA = V Λ Vᵀ ;  U = A V Σ⁻¹
        let g = profile::time(Stage::Gram, || a.t_matmul(a)); // n×n
        let e = jacobi_eigen_blocked(&g); // self-times eigen_sweep/eigen_sort
        let s: Vec<f64> = e.values.iter().take(r).map(|&w| w.max(0.0).sqrt()).collect();
        let v = e.vectors; // n×n
        let av = a.matmul(&v); // m×n
        let mut u = MatF::zeros(m, r);
        for j in 0..r {
            let inv = if s[j] > sv_floor(&s) { 1.0 / s[j] } else { 0.0 };
            for i in 0..m {
                *u.at_mut(i, j) = av.at(i, j) * inv;
            }
        }
        let mut vt = MatF::zeros(r, n);
        for i in 0..r {
            for j in 0..n {
                *vt.at_mut(i, j) = v.at(j, i);
            }
        }
        Svd { u, s, vt }
    }
}

/// Relative floor below which singular triplets are treated as null.
fn sv_floor(s: &[f64]) -> f64 {
    s.first().copied().unwrap_or(0.0) * 1e-12
}

/// A Aᵀ (m×m) without materializing the transpose.
///
/// Lower-triangle rows are computed in parallel bands; each dot product is
/// an independent work unit, so the result is bit-identical for any thread
/// count. The upper triangle is mirrored afterwards (cheap copies).
fn gram_right(a: &MatF) -> MatF {
    let m = a.rows;
    let mut g = MatF::zeros(m, m);
    parallel_row_bands(&mut g.data, m, m, |row0, band| {
        let brows = band.len() / m;
        for ii in 0..brows {
            let i = row0 + ii;
            let ri = a.row(i);
            let grow = &mut band[ii * m..(ii + 1) * m];
            for j in 0..=i {
                let rj = a.row(j);
                grow[j] = ri.iter().zip(rj).map(|(x, y)| x * y).sum();
            }
        }
    });
    for i in 0..m {
        for j in 0..i {
            let s = g.at(i, j);
            *g.at_mut(j, i) = s;
        }
    }
    g
}

impl Svd {
    /// Rank-k truncated reconstruction U_k Σ_k V_kᵀ.
    pub fn reconstruct(&self, k: usize) -> MatF {
        let k = k.min(self.s.len());
        let (m, n) = (self.u.rows, self.vt.cols);
        let mut out = MatF::zeros(m, n);
        for t in 0..k {
            let sv = self.s[t];
            if sv == 0.0 {
                continue;
            }
            for i in 0..m {
                let ui = self.u.at(i, t) * sv;
                if ui == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                let vrow = self.vt.row(t);
                for j in 0..n {
                    orow[j] += ui * vrow[j];
                }
            }
        }
        out
    }

    /// Truncated factors (B, C) with B = U_k Σ_k (m×k), C = V_kᵀ (k×n).
    pub fn factors(&self, k: usize) -> (MatF, MatF) {
        let k = k.min(self.s.len());
        let (m, n) = (self.u.rows, self.vt.cols);
        let mut b = MatF::zeros(m, k);
        for i in 0..m {
            for t in 0..k {
                *b.at_mut(i, t) = self.u.at(i, t) * self.s[t];
            }
        }
        let mut c = MatF::zeros(k, n);
        for t in 0..k {
            c.row_mut(t).copy_from_slice(&self.vt.row(t)[..n]);
        }
        (b, c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::effective_rank;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, m: usize, n: usize) -> MatF {
        MatF::from_vec(m, n, (0..m * n).map(|_| rng.normal()).collect())
    }

    #[test]
    fn full_rank_reconstruction_both_orientations() {
        let mut rng = Rng::new(0);
        for &(m, n) in &[(10, 25), (25, 10), (16, 16), (1, 8), (8, 1)] {
            let a = random(&mut rng, m, n);
            let d = svd(&a);
            let rec = d.reconstruct(m.min(n));
            let err = rec.sub(&a).frob_norm() / a.frob_norm();
            assert!(err < 1e-8, "({m},{n}) err {err}");
        }
    }

    #[test]
    fn singular_values_descending_nonnegative() {
        let mut rng = Rng::new(1);
        let a = random(&mut rng, 30, 12);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(d.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn truncation_is_best_rank_k() {
        // Eckart–Young sanity: rank-k error equals sqrt(sum of tail σ²)
        let mut rng = Rng::new(2);
        let a = random(&mut rng, 20, 14);
        let d = svd(&a);
        for k in [1, 3, 7] {
            let err = d.reconstruct(k).sub(&a).frob_norm();
            let want: f64 = d.s[k..].iter().map(|s| s * s).sum::<f64>().sqrt();
            assert!((err - want).abs() < 1e-8, "k={k}");
        }
    }

    #[test]
    fn factors_match_reconstruction() {
        let mut rng = Rng::new(3);
        let a = random(&mut rng, 12, 18);
        let d = svd(&a);
        let (b, c) = d.factors(5);
        let rec = b.matmul(&c);
        let want = d.reconstruct(5);
        for (x, y) in rec.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn known_rank_detected() {
        // build an exactly rank-3 matrix
        let mut rng = Rng::new(4);
        let b = random(&mut rng, 15, 3);
        let c = random(&mut rng, 3, 22);
        let a = b.matmul(&c);
        let d = svd(&a);
        assert!(d.s[2] > 1e-6);
        assert!(d.s[3] < 1e-6 * d.s[0]);
        let reff = effective_rank(&d.s);
        assert!(reff <= 3.0 + 1e-6 && reff > 1.0, "reff {reff}");
    }

    #[test]
    fn orthonormal_u_v() {
        let mut rng = Rng::new(5);
        let a = random(&mut rng, 9, 21);
        let d = svd(&a);
        let utu = d.u.t_matmul(&d.u);
        let vvt = d.vt.matmul(&d.vt.transpose());
        for i in 0..9 {
            for j in 0..9 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - want).abs() < 1e-8);
                assert!((vvt.at(i, j) - want).abs() < 1e-8);
            }
        }
    }
}
