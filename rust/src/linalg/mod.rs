//! Numerical linear algebra substrate (f64).
//!
//! Everything the compression pipeline needs, built from scratch:
//! Cholesky with adaptive jitter (whitening S from possibly rank-deficient
//! calibration Grams), triangular solves (applying S^-1 without forming an
//! inverse), a cyclic Jacobi symmetric eigensolver (serial reference plus a
//! blocked round-robin variant that parallelizes each sweep over disjoint
//! pivot pairs, bit-identical for any thread count), SVD via the smaller-side
//! Gram eigendecomposition, and the paper's spectral-entropy effective rank.
//!
//! Precision note: the paper computes S in FP64 (§4.1); this module is f64
//! end-to-end and only converts to f32 when handing factors to the runtime.

pub mod eigen;
pub mod svd;

use crate::tensor::MatF;

/// Lower-triangular Cholesky: G = L·Lᵀ for symmetric PSD G.
///
/// Adds an escalating diagonal jitter (relative to mean diagonal) when the
/// matrix is semi-definite — calibration Grams of narrow layers routinely
/// are. Returns (L, jitter_used).
pub fn cholesky_jitter(g: &MatF) -> (MatF, f64) {
    assert_eq!(g.rows, g.cols, "cholesky needs square input");
    let n = g.rows;
    let mean_diag = (0..n).map(|i| g.at(i, i)).sum::<f64>() / n as f64;
    let mut jitter = 0.0;
    for attempt in 0..12 {
        if attempt > 0 {
            jitter = mean_diag.max(1e-300) * 1e-10 * 10f64.powi(attempt - 1);
        }
        if let Some(l) = try_cholesky(g, jitter) {
            return (l, jitter);
        }
    }
    panic!("cholesky failed even with jitter {jitter:.3e}");
}

fn try_cholesky(g: &MatF, jitter: f64) -> Option<MatF> {
    let n = g.rows;
    let mut l = MatF::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = g.at(i, j) + if i == j { jitter } else { 0.0 };
            for k in 0..j {
                s -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                *l.at_mut(i, j) = s.sqrt();
            } else {
                *l.at_mut(i, j) = s / l.at(j, j);
            }
        }
    }
    Some(l)
}

/// Solve L·X = B for lower-triangular L (forward substitution), column-wise.
pub fn solve_lower(l: &MatF, b: &MatF) -> MatF {
    let n = l.rows;
    assert_eq!(b.rows, n);
    let mut x = b.clone();
    for i in 0..n {
        let lii = l.at(i, i);
        for k in 0..i {
            let lik = l.at(i, k);
            if lik == 0.0 {
                continue;
            }
            // x[i,:] -= l[i,k] * x[k,:]
            let (head, tail) = x.data.split_at_mut(i * x.cols);
            let xk = &head[k * x.cols..(k + 1) * x.cols];
            let xi = &mut tail[..x.cols];
            for j in 0..x.cols {
                xi[j] -= lik * xk[j];
            }
        }
        for v in x.row_mut(i) {
            *v /= lii;
        }
    }
    x
}

/// Solve Lᵀ·X = B for lower-triangular L (back substitution), column-wise.
pub fn solve_lower_t(l: &MatF, b: &MatF) -> MatF {
    let n = l.rows;
    assert_eq!(b.rows, n);
    let mut x = b.clone();
    for i in (0..n).rev() {
        let lii = l.at(i, i);
        for k in i + 1..n {
            let lki = l.at(k, i); // (Lᵀ)[i,k]
            if lki == 0.0 {
                continue;
            }
            let (head, tail) = x.data.split_at_mut(k * x.cols);
            let xi = &mut head[i * x.cols..(i + 1) * x.cols];
            let xk = &tail[..x.cols];
            for j in 0..x.cols {
                xi[j] -= lki * xk[j];
            }
        }
        for v in x.row_mut(i) {
            *v /= lii;
        }
    }
    x
}

/// Effective rank of a singular-value spectrum (paper Eq. 1-2):
/// p_i = σ_i² / Σσ²,  R_eff = exp(−Σ p_i ln p_i).
pub fn effective_rank(sigma: &[f64]) -> f64 {
    let total: f64 = sigma.iter().map(|s| s * s).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for s in sigma {
        let p = s * s / total;
        if p > 0.0 {
            h -= p * p.ln();
        }
    }
    h.exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> MatF {
        let a = MatF::from_vec(n, n, (0..n * n).map(|_| rng.normal()).collect());
        let mut g = a.t_matmul(&a);
        for i in 0..n {
            *g.at_mut(i, i) += 0.5;
        }
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(0);
        for n in [1, 3, 17, 64] {
            let g = random_spd(&mut rng, n);
            let (l, jit) = cholesky_jitter(&g);
            assert_eq!(jit, 0.0);
            let rec = l.matmul(&l.transpose());
            for (a, b) in rec.data.iter().zip(&g.data) {
                assert!((a - b).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    fn cholesky_handles_semidefinite() {
        // rank-1 Gram: needs jitter, must not panic
        let v = MatF::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let g = v.t_matmul(&v);
        let (l, jit) = cholesky_jitter(&g);
        assert!(jit > 0.0);
        assert!(l.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn solves_invert_cholesky() {
        let mut rng = Rng::new(1);
        let g = random_spd(&mut rng, 12);
        let (l, _) = cholesky_jitter(&g);
        let b = MatF::from_vec(12, 5, (0..60).map(|_| rng.normal()).collect());
        // L (L^-1 B) == B
        let x = solve_lower(&l, &b);
        let rec = l.matmul(&x);
        for (a, bb) in rec.data.iter().zip(&b.data) {
            assert!((a - bb).abs() < 1e-8);
        }
        // Lᵀ (L^-T B) == B
        let y = solve_lower_t(&l, &b);
        let rec2 = l.transpose().matmul(&y);
        for (a, bb) in rec2.data.iter().zip(&b.data) {
            assert!((a - bb).abs() < 1e-8);
        }
    }

    #[test]
    fn effective_rank_uniform_spectrum() {
        // k equal singular values -> R_eff == k
        let s = vec![2.0; 7];
        assert!((effective_rank(&s) - 7.0).abs() < 1e-10);
    }

    #[test]
    fn effective_rank_single_dominant() {
        let s = [100.0, 1e-8, 1e-8];
        assert!((effective_rank(&s) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn effective_rank_monotone_in_spread() {
        // a flatter spectrum has a larger effective rank
        let flat = effective_rank(&[1.0, 0.9, 0.8, 0.7]);
        let peaked = effective_rank(&[1.0, 0.1, 0.05, 0.01]);
        assert!(flat > peaked);
        assert!(flat <= 4.0 + 1e-9);
        assert!(peaked >= 1.0);
    }

    #[test]
    fn effective_rank_empty_and_zero() {
        assert_eq!(effective_rank(&[]), 0.0);
        assert_eq!(effective_rank(&[0.0, 0.0]), 0.0);
    }
}
