//! Cyclic Jacobi eigensolver for symmetric matrices.
//!
//! Sizes here are <= ~768 (the smaller Gram side of a grouped weight
//! matrix), where Jacobi's O(n³) per sweep with quadratic convergence is
//! fast, simple, and — importantly for effective-rank computation — highly
//! accurate for small eigenvalues compared to tridiagonalization at f64.

use crate::tensor::MatF;

/// Result of a symmetric eigendecomposition A = V diag(w) Vᵀ,
/// eigenvalues sorted descending, V columns the matching eigenvectors.
pub struct Eigen {
    pub values: Vec<f64>,
    pub vectors: MatF, // column i <-> values[i]
}

/// Cyclic Jacobi with threshold sweeping. `a` must be symmetric.
pub fn jacobi_eigen(a: &MatF) -> Eigen {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.clone();
    let mut v = MatF::identity(n);
    if n <= 1 {
        return sort_eigen(vec![if n == 1 { m.at(0, 0) } else { 0.0 }; n.min(1)], v);
    }

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let off: f64 = {
            let mut s = 0.0;
            for i in 0..n {
                for j in i + 1..n {
                    s += m.at(i, j) * m.at(i, j);
                }
            }
            s
        };
        let scale: f64 = m.data.iter().map(|x| x * x).sum();
        if off <= 1e-26 * scale.max(1e-300) {
            break;
        }
        // threshold sweeping: rotations on negligible off-diagonal entries
        // cost O(n) each but reduce the objective by ~0; skipping them cuts
        // late sweeps to near no-ops (measured 1.9x on 192x384 inputs —
        // EXPERIMENTS.md §Perf)
        let thresh = (off / (n * n) as f64).sqrt() * 0.5;
        for p in 0..n - 1 {
            for q in p + 1..n {
                let apq = m.at(p, q);
                if apq.abs() <= thresh || apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                // rotation angle via the stable tau formulation
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    1.0 / (tau - (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                rotate(&mut m, p, q, c, s);
                rotate_cols(&mut v, p, q, c, s);
            }
        }
    }
    let values: Vec<f64> = (0..n).map(|i| m.at(i, i)).collect();
    sort_eigen(values, v)
}

/// Apply the two-sided rotation J(p,q,θ)ᵀ M J(p,q,θ) in place.
fn rotate(m: &mut MatF, p: usize, q: usize, c: f64, s: f64) {
    let n = m.rows;
    for k in 0..n {
        let mkp = m.at(k, p);
        let mkq = m.at(k, q);
        *m.at_mut(k, p) = c * mkp - s * mkq;
        *m.at_mut(k, q) = s * mkp + c * mkq;
    }
    for k in 0..n {
        let mpk = m.at(p, k);
        let mqk = m.at(q, k);
        *m.at_mut(p, k) = c * mpk - s * mqk;
        *m.at_mut(q, k) = s * mpk + c * mqk;
    }
}

/// Accumulate the rotation into the eigenvector matrix (columns p, q).
fn rotate_cols(v: &mut MatF, p: usize, q: usize, c: f64, s: f64) {
    for k in 0..v.rows {
        let vkp = v.at(k, p);
        let vkq = v.at(k, q);
        *v.at_mut(k, p) = c * vkp - s * vkq;
        *v.at_mut(k, q) = s * vkp + c * vkq;
    }
}

fn sort_eigen(values: Vec<f64>, vectors: MatF) -> Eigen {
    let n = values.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| values[b].partial_cmp(&values[a]).unwrap());
    let sorted_vals: Vec<f64> = idx.iter().map(|&i| values[i]).collect();
    let mut sorted_vecs = MatF::zeros(vectors.rows, n);
    for (new_c, &old_c) in idx.iter().enumerate() {
        for r in 0..vectors.rows {
            *sorted_vecs.at_mut(r, new_c) = vectors.at(r, old_c);
        }
    }
    Eigen { values: sorted_vals, vectors: sorted_vecs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sym(rng: &mut Rng, n: usize) -> MatF {
        let mut m = MatF::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let x = rng.normal();
                *m.at_mut(i, j) = x;
                *m.at_mut(j, i) = x;
            }
        }
        m
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::new(0);
        for n in [1, 2, 5, 33, 80] {
            let a = random_sym(&mut rng, n);
            let e = jacobi_eigen(&a);
            // A V = V diag(w)
            let av = a.matmul(&e.vectors);
            for i in 0..n {
                for j in 0..n {
                    let want = e.vectors.at(i, j) * e.values[j];
                    assert!((av.at(i, j) - want).abs() < 1e-8, "n={n}");
                }
            }
        }
    }

    #[test]
    fn eigenvalues_sorted_descending() {
        let mut rng = Rng::new(1);
        let a = random_sym(&mut rng, 20);
        let e = jacobi_eigen(&a);
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn vectors_orthonormal() {
        let mut rng = Rng::new(2);
        let a = random_sym(&mut rng, 25);
        let e = jacobi_eigen(&a);
        let vtv = e.vectors.t_matmul(&e.vectors);
        for i in 0..25 {
            for j in 0..25 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv.at(i, j) - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = MatF::zeros(4, 4);
        for (i, v) in [3.0, -1.0, 7.0, 0.5].iter().enumerate() {
            *a.at_mut(i, i) = *v;
        }
        let e = jacobi_eigen(&a);
        assert_eq!(e.values, vec![7.0, 3.0, 0.5, -1.0]);
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::new(3);
        let a = random_sym(&mut rng, 40);
        let tr: f64 = (0..40).map(|i| a.at(i, i)).sum();
        let e = jacobi_eigen(&a);
        let sum: f64 = e.values.iter().sum();
        assert!((tr - sum).abs() < 1e-8);
    }
}
