//! Jacobi eigensolvers for symmetric matrices: serial cyclic sweeps and a
//! blocked round-robin variant that fans each round's independent rotations
//! out on the `util/parallel.rs` pool.
//!
//! Sizes here are <= ~768 (the smaller Gram side of a grouped weight
//! matrix), where Jacobi's O(n³) per sweep with quadratic convergence is
//! fast, simple, and — importantly for effective-rank computation — highly
//! accurate for small eigenvalues compared to tridiagonalization at f64.
//!
//! **Determinism contract** (EXPERIMENTS.md §Perf): [`jacobi_eigen_blocked`]
//! returns bit-identical `Eigen` output for any thread count. Each sweep is
//! a fixed tournament schedule of rounds; within a round every (p,q) pivot
//! pair is disjoint from every other, rotation angles are read from the
//! round-start matrix (which sequential application would produce too — no
//! other rotation in the round touches the (p,p), (p,q), (q,q) entries),
//! and the three update phases (columns, rows, eigenvector columns) each
//! compute every element by the same instruction sequence regardless of how
//! the work is split. `rust/tests/determinism.rs` enforces the contract
//! across 1/2/4 threads; `rust/tests/eigen_properties.rs` pins the numerics
//! of both solvers against synthesized spectra.

use crate::tensor::MatF;
use crate::util::parallel::{parallel_pair_rows, parallel_row_bands};
use crate::util::profile::{self, Stage};

/// Result of a symmetric eigendecomposition A = V diag(w) Vᵀ,
/// eigenvalues sorted descending, V columns the matching eigenvectors.
pub struct Eigen {
    pub values: Vec<f64>,
    pub vectors: MatF, // column i <-> values[i]
}

/// Convergence ceiling shared by both solvers. Cyclic and round-robin
/// orderings both converge quadratically once sweeps get close; 64 is far
/// above what any <=768 Gram matrix needs.
const MAX_SWEEPS: usize = 64;

/// Debug-only symmetry check: both solvers silently assume A = Aᵀ (they
/// only ever read the entries a rotation owns), so catch asymmetric inputs
/// at the door instead of returning a quietly wrong spectrum.
fn debug_assert_symmetric(a: &MatF) {
    if cfg!(debug_assertions) {
        let scale = a.data.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let tol = scale * 1e-8 + 1e-12;
        for i in 0..a.rows {
            for j in i + 1..a.cols {
                debug_assert!(
                    (a.at(i, j) - a.at(j, i)).abs() <= tol,
                    "eigensolver input not symmetric at ({i},{j}): {} vs {}",
                    a.at(i, j),
                    a.at(j, i)
                );
            }
        }
    }
}

/// n <= 1 never needs a sweep; return a well-formed `Eigen` directly.
/// (The old construction built `values` as `vec![..; n.min(1)]` against an
/// identity-shaped `vectors`, which left the n=0 result malformed.)
fn trivial_eigen(a: &MatF) -> Eigen {
    match a.rows {
        0 => Eigen { values: Vec::new(), vectors: MatF::zeros(0, 0) },
        1 => Eigen { values: vec![a.at(0, 0)], vectors: MatF::identity(1) },
        n => unreachable!("trivial_eigen called with n={n}"),
    }
}

/// Sum of squared strictly-upper-triangle entries (the Jacobi objective).
fn off_diag_sq(m: &MatF) -> f64 {
    let n = m.rows;
    let mut s = 0.0;
    for i in 0..n {
        for j in i + 1..n {
            s += m.at(i, j) * m.at(i, j);
        }
    }
    s
}

/// Stable rotation coefficients (tau formulation) annihilating a_pq.
#[inline]
fn rotation_coeffs(app: f64, aqq: f64, apq: f64) -> (f64, f64) {
    let tau = (aqq - app) / (2.0 * apq);
    let t = if tau >= 0.0 {
        1.0 / (tau + (1.0 + tau * tau).sqrt())
    } else {
        1.0 / (tau - (1.0 + tau * tau).sqrt())
    };
    let c = 1.0 / (1.0 + t * t).sqrt();
    let s = t * c;
    (c, s)
}

/// Round-robin tournament schedule over `n` indices (circle method):
/// `n'-1` rounds (n' = n rounded up to even) of disjoint (p,q) pairs, every
/// unordered pair appearing exactly once per full schedule. Index `n'-1`
/// stays fixed while the rest rotate; when `n` is odd the padded index is a
/// bye and its pair is dropped. The schedule — and the order of pairs
/// within each round — is a pure function of `n`, which is what makes the
/// blocked sweep's canonical rotation order deterministic.
pub fn tournament_rounds(n: usize) -> Vec<Vec<(usize, usize)>> {
    if n < 2 {
        return Vec::new();
    }
    let m = n + (n % 2); // pad with a bye when odd
    let mut rounds = Vec::with_capacity(m - 1);
    for r in 0..m - 1 {
        let mut pairs = Vec::with_capacity(m / 2);
        for k in 0..m / 2 {
            let (a, b) = if k == 0 {
                (m - 1, r % (m - 1))
            } else {
                ((r + k) % (m - 1), (r + m - 1 - k) % (m - 1))
            };
            if a >= n || b >= n {
                continue; // the bye sits out this round
            }
            pairs.push((a.min(b), a.max(b)));
        }
        rounds.push(pairs);
    }
    rounds
}

/// Serial cyclic Jacobi with threshold sweeping. `a` must be symmetric.
///
/// Kept as the reference path: the property suite pins
/// [`jacobi_eigen_blocked`] against it, and single-matrix callers that are
/// already inside a parallel region can use it to avoid nested fan-out.
pub fn jacobi_eigen(a: &MatF) -> Eigen {
    assert_eq!(a.rows, a.cols, "eigensolver needs a square matrix");
    debug_assert_symmetric(a);
    let n = a.rows;
    if n <= 1 {
        return trivial_eigen(a);
    }
    let mut m = a.clone();
    let mut v = MatF::identity(n);

    profile::time(Stage::EigenSweep, || {
        for _sweep in 0..MAX_SWEEPS {
            let off = off_diag_sq(&m);
            let scale: f64 = m.data.iter().map(|x| x * x).sum();
            if off <= 1e-26 * scale.max(1e-300) {
                break;
            }
            // threshold sweeping: rotations on negligible off-diagonal
            // entries cost O(n) each but reduce the objective by ~0;
            // skipping them cuts late sweeps to near no-ops (measured 1.9x
            // on 192x384 inputs — EXPERIMENTS.md §Perf)
            let thresh = (off / (n * n) as f64).sqrt() * 0.5;
            for p in 0..n - 1 {
                for q in p + 1..n {
                    let apq = m.at(p, q);
                    if apq.abs() <= thresh || apq.abs() < 1e-300 {
                        continue;
                    }
                    let (c, s) = rotation_coeffs(m.at(p, p), m.at(q, q), apq);
                    rotate(&mut m, p, q, c, s);
                    rotate_cols(&mut v, p, q, c, s);
                }
            }
        }
    });
    let values: Vec<f64> = (0..n).map(|i| m.at(i, i)).collect();
    profile::time(Stage::EigenSort, || sort_eigen(values, v))
}

/// Blocked round-robin Jacobi: the same threshold-swept rotations as
/// [`jacobi_eigen`], scheduled as tournament rounds of disjoint pivot
/// pairs so each round's updates fan out on the thread pool.
///
/// Per round: (1) rotation angles are computed sequentially from the
/// round-start matrix (O(n) — every pair owns its own 2x2 block, so this
/// matches what in-order application would read); (2) the column phase
/// M <- M·J runs row-band-parallel, each row applying the round's
/// rotations in canonical order; (3) the row phase M <- Jᵀ·M runs
/// pair-parallel — each rotation owns exactly rows p and q, which no other
/// rotation in the round touches; (4) the eigenvector update V <- V·J is
/// another row-band column phase. Every element is produced by a fixed
/// instruction sequence independent of the work split, so the output is
/// bit-identical for any thread count.
pub fn jacobi_eigen_blocked(a: &MatF) -> Eigen {
    assert_eq!(a.rows, a.cols, "eigensolver needs a square matrix");
    debug_assert_symmetric(a);
    let n = a.rows;
    if n <= 1 {
        return trivial_eigen(a);
    }
    let mut m = a.clone();
    let mut v = MatF::identity(n);
    let rounds = tournament_rounds(n);

    profile::time(Stage::EigenSweep, || {
        for _sweep in 0..MAX_SWEEPS {
            let off = off_diag_sq(&m);
            let scale: f64 = m.data.iter().map(|x| x * x).sum();
            if off <= 1e-26 * scale.max(1e-300) {
                break;
            }
            let thresh = (off / (n * n) as f64).sqrt() * 0.5;
            for round in &rounds {
                // (1) angles from the round-start matrix, canonical order
                let rots: Vec<(usize, usize, f64, f64)> = round
                    .iter()
                    .filter_map(|&(p, q)| {
                        let apq = m.at(p, q);
                        if apq.abs() <= thresh || apq.abs() < 1e-300 {
                            return None;
                        }
                        let (c, s) = rotation_coeffs(m.at(p, p), m.at(q, q), apq);
                        Some((p, q, c, s))
                    })
                    .collect();
                if rots.is_empty() {
                    continue;
                }
                // (2) column phase: M <- M·J, one band of whole rows per
                // thread, rotations applied in list order within each row
                parallel_row_bands(&mut m.data, n, n, |_, band| {
                    for row in band.chunks_mut(n) {
                        for &(p, q, c, s) in &rots {
                            let (xp, xq) = (row[p], row[q]);
                            row[p] = c * xp - s * xq;
                            row[q] = s * xp + c * xq;
                        }
                    }
                });
                // (3) row phase: M <- Jᵀ·M; rotation i owns rows pairs[i]
                let pairs: Vec<(usize, usize)> =
                    rots.iter().map(|&(p, q, _, _)| (p, q)).collect();
                parallel_pair_rows(&mut m.data, n, n, &pairs, |i, rp, rq| {
                    let (_, _, c, s) = rots[i];
                    for j in 0..n {
                        let (xp, xq) = (rp[j], rq[j]);
                        rp[j] = c * xp - s * xq;
                        rq[j] = s * xp + c * xq;
                    }
                });
                // (4) accumulate eigenvectors: V <- V·J (columns only)
                parallel_row_bands(&mut v.data, n, n, |_, band| {
                    for row in band.chunks_mut(n) {
                        for &(p, q, c, s) in &rots {
                            let (xp, xq) = (row[p], row[q]);
                            row[p] = c * xp - s * xq;
                            row[q] = s * xp + c * xq;
                        }
                    }
                });
            }
        }
    });
    let values: Vec<f64> = (0..n).map(|i| m.at(i, i)).collect();
    profile::time(Stage::EigenSort, || sort_eigen(values, v))
}

/// Apply the two-sided rotation J(p,q,θ)ᵀ M J(p,q,θ) in place.
fn rotate(m: &mut MatF, p: usize, q: usize, c: f64, s: f64) {
    let n = m.rows;
    for k in 0..n {
        let mkp = m.at(k, p);
        let mkq = m.at(k, q);
        *m.at_mut(k, p) = c * mkp - s * mkq;
        *m.at_mut(k, q) = s * mkp + c * mkq;
    }
    for k in 0..n {
        let mpk = m.at(p, k);
        let mqk = m.at(q, k);
        *m.at_mut(p, k) = c * mpk - s * mqk;
        *m.at_mut(q, k) = s * mpk + c * mqk;
    }
}

/// Accumulate the rotation into the eigenvector matrix (columns p, q).
fn rotate_cols(v: &mut MatF, p: usize, q: usize, c: f64, s: f64) {
    for k in 0..v.rows {
        let vkp = v.at(k, p);
        let vkq = v.at(k, q);
        *v.at_mut(k, p) = c * vkp - s * vkq;
        *v.at_mut(k, q) = s * vkp + c * vkq;
    }
}

fn sort_eigen(values: Vec<f64>, vectors: MatF) -> Eigen {
    let n = values.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| values[b].partial_cmp(&values[a]).unwrap());
    let sorted_vals: Vec<f64> = idx.iter().map(|&i| values[i]).collect();
    let mut sorted_vecs = MatF::zeros(vectors.rows, n);
    for (new_c, &old_c) in idx.iter().enumerate() {
        for r in 0..vectors.rows {
            *sorted_vecs.at_mut(r, new_c) = vectors.at(r, old_c);
        }
    }
    Eigen { values: sorted_vals, vectors: sorted_vecs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sym(rng: &mut Rng, n: usize) -> MatF {
        let mut m = MatF::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let x = rng.normal();
                *m.at_mut(i, j) = x;
                *m.at_mut(j, i) = x;
            }
        }
        m
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::new(0);
        for n in [1, 2, 5, 33, 80] {
            let a = random_sym(&mut rng, n);
            for solve in [jacobi_eigen as fn(&MatF) -> Eigen, jacobi_eigen_blocked] {
                let e = solve(&a);
                // A V = V diag(w)
                let av = a.matmul(&e.vectors);
                for i in 0..n {
                    for j in 0..n {
                        let want = e.vectors.at(i, j) * e.values[j];
                        assert!((av.at(i, j) - want).abs() < 1e-8, "n={n}");
                    }
                }
            }
        }
    }

    #[test]
    fn eigenvalues_sorted_descending() {
        let mut rng = Rng::new(1);
        let a = random_sym(&mut rng, 20);
        for solve in [jacobi_eigen as fn(&MatF) -> Eigen, jacobi_eigen_blocked] {
            let e = solve(&a);
            for w in e.values.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        }
    }

    #[test]
    fn vectors_orthonormal() {
        let mut rng = Rng::new(2);
        let a = random_sym(&mut rng, 25);
        for solve in [jacobi_eigen as fn(&MatF) -> Eigen, jacobi_eigen_blocked] {
            let e = solve(&a);
            let vtv = e.vectors.t_matmul(&e.vectors);
            for i in 0..25 {
                for j in 0..25 {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((vtv.at(i, j) - want).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = MatF::zeros(4, 4);
        for (i, v) in [3.0, -1.0, 7.0, 0.5].iter().enumerate() {
            *a.at_mut(i, i) = *v;
        }
        for solve in [jacobi_eigen as fn(&MatF) -> Eigen, jacobi_eigen_blocked] {
            let e = solve(&a);
            assert_eq!(e.values, vec![7.0, 3.0, 0.5, -1.0]);
        }
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::new(3);
        let a = random_sym(&mut rng, 40);
        let tr: f64 = (0..40).map(|i| a.at(i, i)).sum();
        for solve in [jacobi_eigen as fn(&MatF) -> Eigen, jacobi_eigen_blocked] {
            let e = solve(&a);
            let sum: f64 = e.values.iter().sum();
            assert!((tr - sum).abs() < 1e-8);
        }
    }

    #[test]
    fn degenerate_sizes_are_well_formed() {
        // n=0: empty spectrum AND 0x0 vectors (the old path left these
        // shapes inconsistent); n=1: the sole entry, identity vector
        let empty = MatF::zeros(0, 0);
        for solve in [jacobi_eigen as fn(&MatF) -> Eigen, jacobi_eigen_blocked] {
            let e = solve(&empty);
            assert!(e.values.is_empty());
            assert_eq!((e.vectors.rows, e.vectors.cols), (0, 0));
            assert!(e.vectors.data.is_empty());
        }
        let one = MatF::from_vec(1, 1, vec![-2.5]);
        for solve in [jacobi_eigen as fn(&MatF) -> Eigen, jacobi_eigen_blocked] {
            let e = solve(&one);
            assert_eq!(e.values, vec![-2.5]);
            assert_eq!((e.vectors.rows, e.vectors.cols), (1, 1));
            assert_eq!(e.vectors.data, vec![1.0]);
        }
    }

    #[test]
    fn tournament_schedule_covers_every_pair_once() {
        for n in [2usize, 3, 5, 8, 17, 32] {
            let rounds = tournament_rounds(n);
            let mut seen = std::collections::BTreeSet::new();
            for round in &rounds {
                // pairs within a round are disjoint (the parallel-safety
                // invariant of the blocked sweep)
                let mut used = vec![false; n];
                for &(p, q) in round {
                    assert!(p < q && q < n, "bad pair ({p},{q}) for n={n}");
                    assert!(!used[p] && !used[q], "overlap in round for n={n}");
                    used[p] = true;
                    used[q] = true;
                    assert!(seen.insert((p, q)), "pair ({p},{q}) repeated");
                }
            }
            assert_eq!(seen.len(), n * (n - 1) / 2, "missing pairs for n={n}");
        }
        assert!(tournament_rounds(0).is_empty());
        assert!(tournament_rounds(1).is_empty());
    }

    #[test]
    fn serial_and_blocked_agree_on_spectrum() {
        let mut rng = Rng::new(4);
        for n in [7usize, 24, 61] {
            let a = random_sym(&mut rng, n);
            let es = jacobi_eigen(&a);
            let eb = jacobi_eigen_blocked(&a);
            let scale = es.values.iter().fold(1.0f64, |m, x| m.max(x.abs()));
            for (ws, wb) in es.values.iter().zip(&eb.values) {
                assert!((ws - wb).abs() <= 1e-9 * scale, "n={n}: {ws} vs {wb}");
            }
        }
    }
}
