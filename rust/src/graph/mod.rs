//! Runtime graph builder: the compressed forward pass with *exact* dynamic
//! ranks, constructed in Rust via `XlaBuilder` and compiled by the
//! in-process PJRT client.
//!
//! Rank allocations are data-dependent (effective ranks come from
//! calibration), so they cannot be baked into build-time artifacts; this is
//! the same pattern as vLLM capturing CUDA graphs per shape. Python is
//! never involved. Semantics mirror `python/compile/model.py` exactly —
//! cross-checked against the AOT dense artifact and the pure-Rust forward
//! in the integration tests.
//!
//! Note: the xla crate's `XlaOp::matmul` reads the *lhs* shape for both
//! operands (upstream bug), so this module always uses `dot_general`.

use anyhow::Result;

use crate::model::lowrank::CompressedModel;
use crate::model::{ModelConfig, Weights};
use crate::runtime::{lit_f32, Runtime};
use crate::tensor::Mat32;

const EPS: f32 = 1e-5;
const ROPE_THETA: f32 = 1e4;

type B = xla::XlaBuilder;
type Op = xla::XlaOp;

/// A compiled forward graph + the weight literals it expects (after the
/// leading tokens parameter, in order).
pub struct CompiledForward {
    pub exe: xla::PjRtLoadedExecutable,
    pub weights: Vec<xla::Literal>,
    pub batch: usize,
    pub seq: usize,
    /// vocabulary size of the compiled model (admission control rejects
    /// out-of-range token ids before they reach the gather)
    pub vocab: usize,
}

impl CompiledForward {
    /// Per-token NLL for a [batch, seq] token batch -> [batch, seq-1].
    pub fn nll(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        assert_eq!(tokens.len(), self.batch * self.seq);
        let tok = crate::runtime::lit_i32(tokens, &[self.batch, self.seq])?;
        let mut inputs: Vec<&xla::Literal> = vec![&tok];
        inputs.extend(self.weights.iter());
        let result = self.exe.execute::<&xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }
}

/// Track parameters as they are declared.
struct Params<'a> {
    b: &'a B,
    next: i64,
    literals: Vec<xla::Literal>,
}

impl<'a> Params<'a> {
    fn add(&mut self, name: &str, dims: &[usize], data: &[f32]) -> Result<Op> {
        let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let op = self.b.parameter(self.next, xla::ElementType::F32, &dims_i, name)?;
        self.next += 1;
        self.literals.push(lit_f32(data, dims)?);
        Ok(op)
    }

    fn add_mat(&mut self, name: &str, m: &Mat32) -> Result<Op> {
        self.add(name, &[m.rows, m.cols], &m.data)
    }
}

/// Build + compile the forward NLL graph for a (possibly) compressed model.
/// Dense types emit one matmul; factored types emit x·B then ·C with the
/// exact allocated rank per layer.
pub fn compile_forward(
    rt: &Runtime,
    model: &CompressedModel,
    batch: usize,
    seq: usize,
) -> Result<CompiledForward> {
    let cfg = model.config();
    let builder = B::new(&format!("fwd_{}", cfg.name));
    let mut params = Params { b: &builder, next: 0, literals: Vec::new() };

    let t = seq - 1; // predict positions 1..seq
    let tokens = builder.parameter(
        0,
        xla::ElementType::S32,
        &[batch as i64, seq as i64],
        "tokens",
    )?;
    params.next = 1;
    let inputs = tokens.slice_in_dim1(0, t as i64, 1)?; // [B, T]
    let targets = tokens.slice_in_dim1(1, seq as i64, 1)?; // [B, T]

    let w = &model.base;
    let embed = params.add("embed", &w.tensors[0].shape, &w.tensors[0].data)?;
    let mut x = embed.take(&inputs, 0)?; // [B, T, d]

    let (cos, sin) = rope_constants(&builder, t, cfg.head_dim())?;
    for l in 0..cfg.layers {
        x = layer_block(&builder, &mut params, model, &cfg, l, x, &cos, &sin, batch, t)?;
    }
    let fnorm = params.add("final_norm", &[cfg.d], &w.by_name("final_norm").data)?;
    let x = rmsnorm(&builder, &x, &fnorm)?;
    let lm = params.add("lm_head", &[cfg.d, cfg.vocab], &w.by_name("lm_head").data)?;
    let logits = matmul2(&x, &lm)?; // [B, T, V]

    // nll = logsumexp - picked
    let maxv = logits.reduce_max(&[-1], true)?;
    let shifted = logits.sub_(&maxv)?;
    let logz = shifted
        .exp()?
        .reduce_sum(&[-1], false)?
        .log()?
        .add_(&maxv.reshape(&[batch as i64, t as i64])?)?;
    // one-hot pick via iota == target
    let iota = builder.iota(xla::ElementType::S32, &[cfg.vocab as i64], 0)?;
    let iota_b = iota.broadcast_in_dim(
        &[batch as i64, t as i64, cfg.vocab as i64],
        &[2],
    )?;
    let tgt = targets
        .reshape(&[batch as i64, t as i64, 1])?
        .broadcast_in_dim(&[batch as i64, t as i64, cfg.vocab as i64], &[0, 1, 2])?;
    let onehot = iota_b.eq(&tgt)?.convert(xla::PrimitiveType::F32)?;
    let picked = logits.mul_(&onehot)?.reduce_sum(&[-1], false)?;
    let nll = logz.sub_(&picked)?;

    let comp = builder.build(&builder.tuple(&[nll])?)?;
    let exe = rt.client().compile(&comp)?;
    Ok(CompiledForward { exe, weights: params.literals, batch, seq, vocab: cfg.vocab })
}

/// Convenience: compile the *dense* forward of plain weights.
pub fn compile_dense(
    rt: &Runtime,
    weights: &Weights,
    batch: usize,
    seq: usize,
) -> Result<CompiledForward> {
    let model = CompressedModel::dense_passthrough(weights.clone());
    compile_forward(rt, &model, batch, seq)
}

// --------------------------------------------------------------------------

fn rope_constants(b: &B, t: usize, hd: usize) -> Result<(Op, Op)> {
    let half = hd / 2;
    let mut cos = vec![0.0f32; t * half];
    let mut sin = vec![0.0f32; t * half];
    for p in 0..t {
        for i in 0..half {
            let freq = ROPE_THETA.powf(-(i as f32) / half as f32);
            let ang = p as f32 * freq;
            cos[p * half + i] = ang.cos();
            sin[p * half + i] = ang.sin();
        }
    }
    Ok((
        b.constant_literal(&lit_f32(&cos, &[t, half])?)?,
        b.constant_literal(&lit_f32(&sin, &[t, half])?)?,
    ))
}

/// x[..., :half]*cos - x[..., half:]*sin ‖ x[..., half:]*cos + x[..., :half]*sin
/// x: [B, T, H, hd]; cos/sin: [T, half].
fn apply_rope(x: &Op, cos: &Op, sin: &Op, batch: usize, t: usize, h: usize, hd: usize) -> Result<Op> {
    let half = (hd / 2) as i64;
    let dims = [batch as i64, t as i64, h as i64, half];
    let c = cos.broadcast_in_dim(&dims, &[1, 3])?;
    let s = sin.broadcast_in_dim(&dims, &[1, 3])?;
    let x1 = x.slice_in_dim1(0, half, 3)?;
    let x2 = x.slice_in_dim1(half, hd as i64, 3)?;
    let lo = x1.mul_(&c)?.sub_(&x2.mul_(&s)?)?;
    let hi = x2.mul_(&c)?.add_(&x1.mul_(&s)?)?;
    Ok(lo.concat_in_dim(&[&hi], 3)?)
}

fn rmsnorm(_b: &B, x: &Op, w: &Op) -> Result<Op> {
    let ms = x.mul_(x)?.reduce_mean(&[-1], true)?;
    let builder = x.builder();
    let eps = builder.c0(EPS)?;
    let dims = x.dims()?;
    let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    let inv = ms.add_(&eps)?.rsqrt()?;
    let wb = w.broadcast_in_dim(&dims_i, &[dims_i.len() as i64 - 1])?;
    Ok(x.mul_(&inv)?.mul_(&wb)?)
}

/// [B, T, d1] x [d1, d2] -> [B, T, d2].
fn matmul2(x: &Op, w: &Op) -> Result<Op> {
    Ok(x.dot_general(w, &[2], &[0], &[], &[])?)
}

/// Apply a (possibly factored) projection for layer l of `typ`.
fn project(
    params: &mut Params,
    model: &CompressedModel,
    typ: &str,
    l: usize,
    x: &Op,
) -> Result<Op> {
    if let Some((bmat, cmat)) = model.layer_factors(typ, l) {
        let bp = params.add_mat(&format!("{typ}_{l}_b"), bmat)?;
        let cp = params.add_mat(&format!("{typ}_{l}_c"), cmat)?;
        matmul2(&matmul2(x, &bp)?, &cp)
    } else {
        let pidx = ModelConfig::param_index(typ);
        let m = model.base.tensors[pidx].layer_mat(l);
        let wp = params.add_mat(&format!("{typ}_{l}"), &m)?;
        matmul2(x, &wp)
    }
}

#[allow(clippy::too_many_arguments)]
fn layer_block(
    b: &B,
    params: &mut Params,
    model: &CompressedModel,
    cfg: &ModelConfig,
    l: usize,
    x: Op,
    cos: &Op,
    sin: &Op,
    batch: usize,
    t: usize,
) -> Result<Op> {
    let (d, h, kvh, hd) = (cfg.d, cfg.heads, cfg.kv_heads, cfg.head_dim());
    let (bi, ti) = (batch as i64, t as i64);
    let w = &model.base;

    // ---- attention ----
    let an_data = &w.by_name("attn_norm").data[l * d..(l + 1) * d];
    let an = params.add(&format!("attn_norm_{l}"), &[d], an_data)?;
    let xn = rmsnorm(b, &x, &an)?;

    let q = project(params, model, "wq", l, &xn)?
        .reshape(&[bi, ti, h as i64, hd as i64])?;
    let k = project(params, model, "wk", l, &xn)?
        .reshape(&[bi, ti, kvh as i64, hd as i64])?;
    let v = project(params, model, "wv", l, &xn)?
        .reshape(&[bi, ti, kvh as i64, hd as i64])?;
    let q = apply_rope(&q, cos, sin, batch, t, h, hd)?;
    let k = apply_rope(&k, cos, sin, batch, t, kvh, hd)?;
    // GQA: repeat kv heads
    let (k, v) = if kvh != h {
        let rep = (h / kvh) as i64;
        let expand = |op: &Op| -> Result<Op> {
            op.reshape(&[bi, ti, kvh as i64, 1, hd as i64])?
                .broadcast_in_dim(
                    &[bi, ti, kvh as i64, rep, hd as i64],
                    &[0, 1, 2, 3, 4],
                )?
                .reshape(&[bi, ti, h as i64, hd as i64])
                .map_err(anyhow::Error::from)
        };
        (expand(&k)?, expand(&v)?)
    } else {
        (k, v)
    };
    // [B, T, H, hd] -> [B, H, T, hd]
    let qt = q.transpose(&[0, 2, 1, 3])?;
    let kt = k.transpose(&[0, 2, 1, 3])?;
    let vt = v.transpose(&[0, 2, 1, 3])?;
    let scale = b.c0(1.0f32 / (hd as f32).sqrt())?;
    let scores = qt
        .dot_general(&kt, &[3], &[3], &[0, 1], &[0, 1])?
        .mul_(&scale.broadcast_in_dim(&[bi, h as i64, ti, ti], &[])?)?;
    // causal mask
    let qi = b.iota(xla::ElementType::S32, &[ti, ti], 0)?;
    let ki = b.iota(xla::ElementType::S32, &[ti, ti], 1)?;
    let mask = ki.le(&qi)?.broadcast_in_dim(&[bi, h as i64, ti, ti], &[2, 3])?;
    let neg = b.c0(-1e30f32)?.broadcast_in_dim(&[bi, h as i64, ti, ti], &[])?;
    let scores = mask.select(&scores, &neg)?;
    let probs = scores.softmax(-1)?;
    let ctx = probs.dot_general(&vt, &[3], &[2], &[0, 1], &[0, 1])?; // [B,H,T,hd]
    let ctx = ctx.transpose(&[0, 2, 1, 3])?.reshape(&[bi, ti, d as i64])?;
    let attn_out = project(params, model, "wo", l, &ctx)?;
    let x = x.add_(&attn_out)?;

    // ---- mlp ----
    let mn_data = &w.by_name("mlp_norm").data[l * d..(l + 1) * d];
    let mn = params.add(&format!("mlp_norm_{l}"), &[d], mn_data)?;
    let xm = rmsnorm(b, &x, &mn)?;
    let g = project(params, model, "w_gate", l, &xm)?;
    let u = project(params, model, "w_up", l, &xm)?;
    let hmid = g.silu()?.mul_(&u)?;
    let mlp_out = project(params, model, "w_down", l, &hmid)?;
    Ok(x.add_(&mlp_out)?)
}
