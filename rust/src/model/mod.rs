//! tinylm model zoo: configs, weights, checkpoints, reference forward.
//!
//! Shape configs mirror `python/compile/model.py::CONFIGS` exactly (the
//! manifest is cross-checked at load time). *Logical* models map the
//! paper's LLM families onto shape configs + training seeds/corpora:
//!
//! | paper model   | logical | shape config | notes                        |
//! |---------------|---------|--------------|------------------------------|
//! | LLaMA-7B      | `m`     | m            | main workhorse               |
//! | LLaMA-2-7B    | `m2`    | m            | different seed + corpus mix  |
//! | LLaMA-13B     | `l`     | l            | scale axis                   |
//! | LLaMA-30B     | —       | l            | (folded into `l`)            |
//! | LLaMA-3-8B    | `gqa`   | gqa          | grouped-query attention      |
//! | Mistral-7B    | `mist`  | mist         | GQA, wider MLP               |

pub mod fwd;
pub mod lowrank;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::matmul::PackedMat;
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Canonical parameter names, in artifact wire order.
pub const PARAM_NAMES: [&str; 12] = [
    "embed", "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate",
    "w_up", "w_down", "final_norm", "lm_head",
];

/// Compressible weight types (paper's 7), canonical order.
pub const COMPRESSIBLE: [&str; 7] =
    ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

/// Shape configuration of a tinylm variant (mirrors python Config).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub dff: usize,
    pub seq: usize,
    pub batch: usize,
}

pub const CONFIGS: [ModelConfig; 6] = [
    ModelConfig { name: "tiny", vocab: 256, d: 64, layers: 2, heads: 4, kv_heads: 4, dff: 176, seq: 64, batch: 2 },
    ModelConfig { name: "s", vocab: 512, d: 64, layers: 4, heads: 4, kv_heads: 4, dff: 176, seq: 96, batch: 4 },
    ModelConfig { name: "m", vocab: 512, d: 96, layers: 6, heads: 6, kv_heads: 6, dff: 256, seq: 96, batch: 4 },
    ModelConfig { name: "l", vocab: 512, d: 128, layers: 8, heads: 8, kv_heads: 8, dff: 344, seq: 96, batch: 4 },
    ModelConfig { name: "gqa", vocab: 512, d: 96, layers: 6, heads: 6, kv_heads: 2, dff: 256, seq: 96, batch: 4 },
    ModelConfig { name: "mist", vocab: 512, d: 96, layers: 6, heads: 6, kv_heads: 3, dff: 288, seq: 96, batch: 4 },
];

impl ModelConfig {
    pub fn by_name(name: &str) -> Result<ModelConfig> {
        CONFIGS
            .iter()
            .find(|c| c.name == name)
            .copied()
            .ok_or_else(|| anyhow!("unknown config {name}"))
    }

    pub fn head_dim(&self) -> usize {
        self.d / self.heads
    }

    pub fn kvd(&self) -> usize {
        self.kv_heads * self.head_dim()
    }

    pub fn is_gqa(&self) -> bool {
        self.kv_heads < self.heads
    }

    /// Parameter shapes in canonical order.
    pub fn param_shapes(&self) -> Vec<(&'static str, Vec<usize>)> {
        let (l, d, dff, v, kvd) = (self.layers, self.d, self.dff, self.vocab, self.kvd());
        vec![
            ("embed", vec![v, d]),
            ("attn_norm", vec![l, d]),
            ("wq", vec![l, d, d]),
            ("wk", vec![l, d, kvd]),
            ("wv", vec![l, d, kvd]),
            ("wo", vec![l, d, d]),
            ("mlp_norm", vec![l, d]),
            ("w_gate", vec![l, d, dff]),
            ("w_up", vec![l, d, dff]),
            ("w_down", vec![l, dff, d]),
            ("final_norm", vec![d]),
            ("lm_head", vec![d, v]),
        ]
    }

    /// (d1, d2) of one layer's matrix of a compressible type
    /// (row-vector convention, y = x·W, d1 = input dim).
    pub fn matrix_dims(&self, typ: &str) -> (usize, usize) {
        let (d, dff, kvd) = (self.d, self.dff, self.kvd());
        match typ {
            "wq" => (d, d),
            "wk" => (d, kvd),
            "wv" => (d, kvd),
            "wo" => (d, d),
            "w_gate" => (d, dff),
            "w_up" => (d, dff),
            "w_down" => (dff, d),
            _ => panic!("not compressible: {typ}"),
        }
    }

    /// Break-even rank of a type: above this, factors cost more than dense.
    pub fn kmax(&self, typ: &str) -> usize {
        let (d1, d2) = self.matrix_dims(typ);
        (d1 * d2) / (d1 + d2)
    }

    /// Index of a compressible type in the canonical param list.
    pub fn param_index(typ: &str) -> usize {
        match typ {
            "wq" => 2,
            "wk" => 3,
            "wv" => 4,
            "wo" => 5,
            "w_gate" => 7,
            "w_up" => 8,
            "w_down" => 9,
            _ => panic!("not compressible: {typ}"),
        }
    }

    /// Total parameters across all compressible matrices.
    pub fn compressible_params(&self) -> usize {
        COMPRESSIBLE
            .iter()
            .map(|t| {
                let (d1, d2) = self.matrix_dims(t);
                self.layers * d1 * d2
            })
            .sum()
    }
}

/// A named tensor (flat f32, row-major).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// View layer `l` of a stacked [L, r, c] tensor as a Mat32 copy.
    pub fn layer_mat(&self, l: usize) -> crate::tensor::Mat32 {
        assert_eq!(self.shape.len(), 3);
        let (r, c) = (self.shape[1], self.shape[2]);
        let off = l * r * c;
        crate::tensor::Mat32::from_vec(r, c, self.data[off..off + r * c].to_vec())
    }

    /// Overwrite layer `l` of a stacked [L, r, c] tensor.
    pub fn set_layer_mat(&mut self, l: usize, m: &crate::tensor::Mat32) {
        assert_eq!(self.shape.len(), 3);
        let (r, c) = (self.shape[1], self.shape[2]);
        assert_eq!((m.rows, m.cols), (r, c));
        let off = l * r * c;
        self.data[off..off + r * c].copy_from_slice(&m.data);
    }
}

/// Rope base frequency (shared by the batched forward, the decode path,
/// and the scalar test oracles).
pub const ROPE_THETA: f32 = 1e4;

/// Precomputed rotary-embedding tables for `t` positions at one head_dim:
/// entry `[p·half + i]` is cos/sin of `p · θ^(−i/half)`. Entries depend
/// only on the position `p` and lane `i` — never on `t` — so tables of
/// different lengths agree bitwise on their shared prefix; decode indexes a
/// capacity-length table by absolute position and matches prefill exactly.
#[derive(Debug)]
pub struct RopeTables {
    /// head_dim / 2 — the per-position stride of `cos`/`sin`.
    pub half: usize,
    pub cos: Vec<f32>,
    pub sin: Vec<f32>,
}

impl RopeTables {
    fn build(t: usize, head_dim: usize) -> Self {
        let half = head_dim / 2;
        // the frequency depends only on the lane, not the position: compute
        // the `half` powf calls once instead of t×half times
        let freqs: Vec<f32> =
            (0..half).map(|i| ROPE_THETA.powf(-(i as f32) / half as f32)).collect();
        let mut cos = vec![0.0f32; t * half];
        let mut sin = vec![0.0f32; t * half];
        for p in 0..t {
            for (i, &freq) in freqs.iter().enumerate() {
                let ang = p as f32 * freq;
                cos[p * half + i] = ang.cos();
                sin[p * half + i] = ang.sin();
            }
        }
        RopeTables { half, cos, sin }
    }

    /// Number of positions this table covers.
    pub fn positions(&self) -> usize {
        if self.half == 0 { 0 } else { self.cos.len() / self.half }
    }
}

fn rope_registry() -> &'static Mutex<HashMap<(usize, usize), Arc<RopeTables>>> {
    static REG: OnceLock<Mutex<HashMap<(usize, usize), Arc<RopeTables>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Memoized rope tables, keyed by `(t, head_dim)` in a process-global
/// registry — built once per shape instead of on every forward call (decode
/// would otherwise rebuild them for every emitted token). The tables are
/// pure functions of their key, so sharing across models and workers is
/// always sound; callers hold an `Arc` so [`reset_rope_tables`] never
/// invalidates a table in use.
pub fn rope_tables(t: usize, head_dim: usize) -> Arc<RopeTables> {
    let mut reg = rope_registry().lock().unwrap();
    reg.entry((t, head_dim))
        .or_insert_with(|| Arc::new(RopeTables::build(t, head_dim)))
        .clone()
}

/// Drop every memoized rope table. Called alongside [`Weights::reset_packs`]
/// so the two serving caches reset together; purely a memory release —
/// tables are deterministic functions of their key, so a rebuilt table is
/// bitwise identical to the dropped one.
pub fn reset_rope_tables() {
    rope_registry().lock().unwrap().clear();
}

/// Test probe: number of distinct `(t, head_dim)` tables currently cached.
pub fn rope_tables_cached() -> usize {
    rope_registry().lock().unwrap().len()
}

/// Lazily-packed GEMM panels for every dense projection site of a model:
/// one slot per (compressible type, layer) plus one for `lm_head`. Weights
/// are reused across every batch, so the serving forward packs each slab
/// into a [`PackedMat`] exactly once (`OnceLock`) on first use and reuses
/// the panels for the lifetime of the `Weights` — including across
/// coordinator workers, which share the model behind an `Arc`.
///
/// Invariant: a slot must never be initialized before the tensor it shadows
/// has its final bytes. All in-place weight mutation in the repo (trainer
/// steps, LoRA merge, `to_dense`) happens on freshly constructed or
/// freshly cloned `Weights` before any forward, and `Clone` resets the
/// registry; `reset_packs` is the explicit escape hatch for mutators.
#[derive(Debug, Default)]
pub struct PackRegistry {
    layers: usize,
    slots: Vec<OnceLock<PackedMat>>,
}

impl PackRegistry {
    pub fn new(config: &ModelConfig) -> Self {
        let layers = config.layers;
        PackRegistry {
            layers,
            slots: (0..COMPRESSIBLE.len() * layers + 1).map(|_| OnceLock::new()).collect(),
        }
    }

    /// The pack slot of one (compressible type, layer) projection site.
    pub fn site(&self, typ: &str, layer: usize) -> &OnceLock<PackedMat> {
        let ti = COMPRESSIBLE.iter().position(|&t| t == typ).expect("not compressible");
        assert!(layer < self.layers, "layer out of range");
        &self.slots[ti * self.layers + layer]
    }

    /// The pack slot of the lm_head projection.
    pub fn lm_head(&self) -> &OnceLock<PackedMat> {
        &self.slots[COMPRESSIBLE.len() * self.layers]
    }

    /// Number of slots already packed (test probe).
    pub fn packed_sites(&self) -> usize {
        self.slots.iter().filter(|s| s.get().is_some()).count()
    }
}

/// Dense model weights (canonical order).
pub struct Weights {
    pub config: ModelConfig,
    pub tensors: Vec<Tensor>,
    /// Per-site packed-panel cache for the serving GEMM (not part of the
    /// model state proper: never saved, reset on clone).
    pub packs: PackRegistry,
}

impl Clone for Weights {
    fn clone(&self) -> Self {
        // A clone is typically about to be mutated (`to_dense`, LoRA merge),
        // so it starts with an empty pack cache rather than sharing panels
        // that could go stale.
        Weights {
            config: self.config,
            tensors: self.tensors.clone(),
            packs: PackRegistry::new(&self.config),
        }
    }
}

impl Weights {
    /// Normal(0, 0.02) init, norms at 1.
    pub fn init(config: ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let tensors = config
            .param_shapes()
            .into_iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                let data = if name.contains("norm") {
                    vec![1.0f32; n]
                } else {
                    (0..n).map(|_| 0.02 * rng.normal() as f32).collect()
                };
                Tensor { shape, data }
            })
            .collect();
        Self { config, tensors, packs: PackRegistry::new(&config) }
    }

    /// Drop all cached GEMM panels (and the process-global rope tables,
    /// which reset alongside the packs). Call after mutating `tensors` in
    /// place on a model that may already have served a forward pass.
    pub fn reset_packs(&mut self) {
        self.packs = PackRegistry::new(&self.config);
        reset_rope_tables();
    }

    pub fn by_name(&self, name: &str) -> &Tensor {
        let idx = PARAM_NAMES.iter().position(|&n| n == name).unwrap();
        &self.tensors[idx]
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    // ---- checkpoint format: "TLMW1" + u32 header len + json + raw f32 LE --

    pub fn save(&self, path: &str, step: usize) -> Result<()> {
        let header = Json::obj(vec![
            ("config", Json::str(self.config.name)),
            ("step", Json::num(step as f64)),
            (
                "shapes",
                Json::Arr(
                    self.tensors
                        .iter()
                        .map(|t| Json::arr_num(&t.shape.iter().map(|&x| x as f64).collect::<Vec<_>>()))
                        .collect(),
                ),
            ),
        ])
        .emit();
        let mut out = Vec::with_capacity(self.total_params() * 4 + header.len() + 16);
        out.extend_from_slice(b"TLMW1");
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for t in &self.tensors {
            for &x in &t.data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, out).with_context(|| format!("writing {path}"))
    }

    pub fn load(path: &str) -> Result<(Self, usize)> {
        let raw = std::fs::read(path).with_context(|| format!("reading {path}"))?;
        if raw.len() < 9 || &raw[..5] != b"TLMW1" {
            bail!("{path}: not a TLMW1 checkpoint");
        }
        let hlen = u32::from_le_bytes(raw[5..9].try_into().unwrap()) as usize;
        let header = std::str::from_utf8(&raw[9..9 + hlen])?;
        let j = Json::parse(header).map_err(|e| anyhow!("{path}: {e}"))?;
        let config = ModelConfig::by_name(
            j.get("config").and_then(|c| c.as_str()).unwrap_or(""),
        )?;
        let step = j.get("step").and_then(|s| s.as_usize()).unwrap_or(0);
        let mut tensors = Vec::new();
        let mut off = 9 + hlen;
        for (_, shape) in config.param_shapes() {
            let n: usize = shape.iter().product();
            if off + n * 4 > raw.len() {
                bail!("{path}: truncated checkpoint");
            }
            let data: Vec<f32> = raw[off..off + n * 4]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            off += n * 4;
            tensors.push(Tensor { shape, data });
        }
        Ok((Self { config, tensors, packs: PackRegistry::new(&config) }, step))
    }
}

/// Logical models: paper family -> (shape config, train seed, corpus seed).
pub fn logical_model(name: &str) -> Result<(ModelConfig, u64)> {
    let (cfg, seed) = match name {
        "tiny" => ("tiny", 100),
        "s" => ("s", 101),
        "m" => ("m", 102),      // LLaMA-7B analog
        "m2" => ("m", 202),     // LLaMA-2-7B analog: same shapes, new seed
        "l" => ("l", 103),      // LLaMA-13B analog
        "gqa" => ("gqa", 104),  // LLaMA-3-8B analog
        "mist" => ("mist", 105),// Mistral-7B analog
        _ => bail!("unknown logical model {name}"),
    };
    Ok((ModelConfig::by_name(cfg)?, seed))
}

/// Default checkpoint path for a logical model.
pub fn ckpt_path(model: &str) -> String {
    format!("runs/{model}/model.bin")
}

/// Load a logical model's checkpoint; when `init_if_missing`, fall back to
/// random-init weights if no checkpoint *file* exists. A checkpoint that
/// exists but fails to parse is always a hard error — corruption must
/// never be silently replaced with random weights.
pub fn load_or_init(model: &str, init_if_missing: bool) -> Result<Weights> {
    let path = ckpt_path(model);
    if std::path::Path::new(&path).exists() {
        let (w, step) = Weights::load(&path)?;
        eprintln!("loaded {path} (step {step})");
        return Ok(w);
    }
    if init_if_missing {
        let (cfg, seed) = logical_model(model)?;
        eprintln!("no checkpoint at {path}; using random-init '{}' weights", cfg.name);
        return Ok(Weights::init(cfg, seed));
    }
    bail!("no checkpoint for '{model}' — run `drank train --model {model}` first")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_match_python() {
        let m = ModelConfig::by_name("m").unwrap();
        assert_eq!((m.d, m.layers, m.dff, m.vocab), (96, 6, 256, 512));
        let g = ModelConfig::by_name("gqa").unwrap();
        assert!(g.is_gqa());
        assert_eq!(g.kvd(), 32); // slimmed kv: 2 heads * 16
        assert_eq!(g.matrix_dims("wk"), (96, 32));
    }

    #[test]
    fn kmax_is_break_even() {
        let m = ModelConfig::by_name("m").unwrap();
        let k = m.kmax("wq");
        let (d1, d2) = m.matrix_dims("wq");
        assert!(k * (d1 + d2) <= d1 * d2);
        assert!((k + 1) * (d1 + d2) > d1 * d2);
    }

    #[test]
    fn init_statistics() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 0);
        let wq = &w.tensors[2];
        let mean: f32 = wq.data.iter().sum::<f32>() / wq.numel() as f32;
        let var: f32 =
            wq.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / wq.numel() as f32;
        assert!(mean.abs() < 2e-3);
        assert!((var.sqrt() - 0.02).abs() < 2e-3);
        // norms are ones
        assert!(w.by_name("attn_norm").data.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn checkpoint_roundtrip() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 7);
        let path = "/tmp/drank_test_ckpt.bin";
        w.save(path, 123).unwrap();
        let (w2, step) = Weights::load(path).unwrap();
        assert_eq!(step, 123);
        assert_eq!(w2.config.name, "tiny");
        for (a, b) in w.tensors.iter().zip(&w2.tensors) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn layer_mat_roundtrip() {
        let mut t = Tensor::zeros(vec![2, 3, 4]);
        let m = crate::tensor::Mat32::from_vec(3, 4, (0..12).map(|x| x as f32).collect());
        t.set_layer_mat(1, &m);
        assert_eq!(t.layer_mat(1).data, m.data);
        assert!(t.layer_mat(0).data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn rope_registry_memoizes_by_shape_and_prefixes_agree() {
        // same key -> the same Arc (no rebuild); the registry is process-
        // global and other tests may insert concurrently, so assert only on
        // our own keys, never on the global count.
        let a = rope_tables(48, 16);
        let b = rope_tables(48, 16);
        assert!(Arc::ptr_eq(&a, &b), "same (t, head_dim) must share one table");
        assert_eq!(a.half, 8);
        assert_eq!(a.positions(), 48);
        // entries depend only on (position, lane): a longer table agrees
        // bitwise with a shorter one over the shared positions — this is
        // what lets decode index a capacity-length table by absolute
        // position and still match prefill exactly.
        let long = rope_tables(96, 16);
        assert_eq!(&long.cos[..a.cos.len()], &a.cos[..]);
        assert_eq!(&long.sin[..a.sin.len()], &a.sin[..]);
        // reset drops cached entries; a rebuilt table is bitwise identical
        // (held Arcs stay valid across the reset)
        reset_rope_tables();
        let c = rope_tables(48, 16);
        assert!(!Arc::ptr_eq(&a, &c), "reset must drop the cached entry");
        assert_eq!(a.cos, c.cos);
        assert_eq!(a.sin, c.sin);
    }

    #[test]
    fn reset_packs_also_resets_rope_registry() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let mut w = Weights::init(cfg, 9);
        let before = rope_tables(31, cfg.head_dim());
        w.reset_packs();
        let after = rope_tables(31, cfg.head_dim());
        assert!(!Arc::ptr_eq(&before, &after), "reset_packs must clear rope tables");
        assert_eq!(before.cos, after.cos);
        assert_eq!(w.packs.packed_sites(), 0);
    }

    #[test]
    fn logical_models_resolve() {
        for name in ["tiny", "s", "m", "m2", "l", "gqa", "mist"] {
            logical_model(name).unwrap();
        }
        assert!(logical_model("nope").is_err());
        // m and m2 share shapes but differ in seed
        let (c1, s1) = logical_model("m").unwrap();
        let (c2, s2) = logical_model("m2").unwrap();
        assert_eq!(c1.name, c2.name);
        assert_ne!(s1, s2);
    }
}
