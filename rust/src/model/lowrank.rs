//! Compressed model representation: per-type dense or factored weights.
//!
//! A factored type stores per *group* a shared basis B (d1×k_g) and
//! per-layer coefficients C⁽ⁱ⁾ (k_g×d2) — the Basis-Sharing layout the
//! paper builds on (n=1 groups degenerate to plain SVD-LLM factors).
//!
//! Three execution paths consume this:
//!  - [`CompressedModel::linear`] resolves each (type, layer) projection
//!    site to a [`Linear`] operator — `Dense` (the base weight slab) or
//!    `Factored` (B, C) — which the pure-Rust forward (`model::fwd`), the
//!    reference calibrator, evaluator, and `RefBackend` all execute
//!    directly: a factored site runs as two skinny GEMMs `(x·B)·C` and the
//!    removed parameters are never rematerialized;
//!  - `to_dense()` reconstructs W ≈ B·C per layer and reuses the AOT dense
//!    artifact (bit-accurate PPL/zero-shot evaluation, no recompilation);
//!  - `graph::build_compressed` emits the *factored* matmuls with the exact
//!    allocated ranks for the runtime throughput path.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::{ModelConfig, Weights, COMPRESSIBLE};
use crate::tensor::{
    matmul::{
        gemm_f32, gemm_f32_packed, gemm_f32_packed_into, matmul_f32, vecmat_f32_packed,
        PackedMat,
    },
    Mat32,
};
use crate::util::profile::{self, Stage};

thread_local! {
    // Per-thread scratch for the (x·B) intermediate of the fused factored
    // path. Grow-only: after the first call at a given working-set size the
    // buffer is just reused, so steady-state serving does zero per-call heap
    // allocations for the intermediate.
    static MID_SCRATCH: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

static SCRATCH_GROWS: AtomicU64 = AtomicU64::new(0);

/// Number of times the fused factored path had to (re)grow a thread-local
/// intermediate buffer. Flat across repeated calls after warmup — the
/// zero-per-call-allocation contract, asserted in `rust/tests/packing.rs`.
pub fn scratch_grows() -> u64 {
    SCRATCH_GROWS.load(Ordering::Relaxed)
}

fn with_mid_scratch<R>(n: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    MID_SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < n {
            SCRATCH_GROWS.fetch_add(1, Ordering::Relaxed);
            buf.resize(n, 0.0);
        }
        f(&mut buf[..n])
    })
}

/// One projection site y = x·W, resolved to its cheapest executable form.
///
/// Every consumer of model weights on the pure-Rust path goes through this
/// enum: `Dense` borrows the layer's slab of the base weight tensor,
/// `Factored` borrows the group basis and the layer's coefficient block.
/// [`Linear::matmul`] is the single place serving FLOPs are spent (and
/// profiled: `Stage::Fwd` vs `Stage::FwdLowrank`).
#[derive(Clone, Copy, Debug)]
pub enum Linear<'a> {
    /// dense d1×d2 weight slab (row-major); `pack` is the site's cached
    /// panel slot (None = no cache, run the unpacked kernel)
    Dense {
        w: &'a [f32],
        d1: usize,
        d2: usize,
        pack: Option<&'a OnceLock<PackedMat>>,
    },
    /// factored W ≈ B·C: B is d1×k, C is k×d2; `pack` caches both factors
    Factored {
        b: &'a Mat32,
        c: &'a Mat32,
        pack: Option<(&'a OnceLock<PackedMat>, &'a OnceLock<PackedMat>)>,
    },
}

impl Linear<'_> {
    /// (input dim, output dim) of the projection.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            Linear::Dense { d1, d2, .. } => (*d1, *d2),
            Linear::Factored { b, c, .. } => (b.rows, c.cols),
        }
    }

    /// y = x·W for `rows` row-major activation rows.
    ///
    /// Dense runs one m×d1×d2 GEMM; factored runs two skinny GEMMs
    /// `(x·B)·C` — cheaper whenever rank k is below the break-even
    /// `d1·d2/(d1+d2)` (`ModelConfig::kmax`), which the rank allocator
    /// guarantees. Sites resolved through a model (`CompressedModel::linear`
    /// / `Params::linear`) carry a pack slot: the weight is packed into
    /// block-major panels once on first use (`OnceLock`), then every call
    /// runs the packed kernel; the factored form additionally fuses
    /// `(x·B)·C` through one per-thread scratch buffer so the intermediate
    /// is never allocated per call. Packed and unpacked kernels are
    /// byte-identical (`tensor::matmul`), so all paths inherit `gemm_f32`'s
    /// bit-determinism for any thread count.
    pub fn matmul(&self, x: &[f32], rows: usize) -> Vec<f32> {
        match self {
            Linear::Dense { w, d1, d2, pack } => profile::time(Stage::Fwd, || match pack {
                Some(slot) => {
                    let bp = slot.get_or_init(|| PackedMat::pack(w, *d1, *d2));
                    gemm_f32_packed(x, rows, *d1, bp)
                }
                None => gemm_f32(x, rows, *d1, w, *d2),
            }),
            Linear::Factored { b, c, pack } => profile::time(Stage::FwdLowrank, || match pack {
                Some((bslot, cslot)) => {
                    let bp = bslot.get_or_init(|| PackedMat::pack(&b.data, b.rows, b.cols));
                    let cp = cslot.get_or_init(|| PackedMat::pack(&c.data, c.rows, c.cols));
                    let mut out = vec![0.0f32; rows * c.cols];
                    with_mid_scratch(rows * b.cols, |mid| {
                        gemm_f32_packed_into(x, rows, b.rows, bp, mid);
                        gemm_f32_packed_into(mid, rows, c.rows, cp, &mut out);
                    });
                    out
                }
                None => {
                    let mid = gemm_f32(x, rows, b.rows, &b.data, b.cols);
                    gemm_f32(&mid, rows, c.rows, &c.data, c.cols)
                }
            }),
        }
    }

    /// y = x·W for a single activation row — the decode hot path, where
    /// every projection sees exactly one token. `y` is overwritten (may be
    /// dirty). Same dispatch and pack slots as [`Linear::matmul`] but
    /// through the serial packed vecmat kernel
    /// (`tensor::matmul::vecmat_f32_packed`): never re-packs a site a
    /// forward pass already packed, does no spawns (trivially
    /// thread-invariant), and the factored form fuses `(x·B)·C` through the
    /// same per-thread scratch as the batched path. Byte-identical to
    /// `matmul(x, 1)` — prefill and decode agree bitwise row for row.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        match self {
            Linear::Dense { w, d1, d2, pack } => profile::time(Stage::Fwd, || {
                assert_eq!(x.len(), *d1, "matvec input dim mismatch");
                assert_eq!(y.len(), *d2, "matvec output dim mismatch");
                match pack {
                    Some(slot) => {
                        let bp = slot.get_or_init(|| PackedMat::pack(w, *d1, *d2));
                        vecmat_f32_packed(x, bp, y);
                    }
                    None => {
                        // unpacked fallback: plain ascending k, the same
                        // per-element order as the packed kernel
                        y.fill(0.0);
                        for (kk, &xv) in x.iter().enumerate() {
                            let wrow = &w[kk * *d2..(kk + 1) * *d2];
                            for (o, &wv) in y.iter_mut().zip(wrow) {
                                *o += xv * wv;
                            }
                        }
                    }
                }
            }),
            Linear::Factored { b, c, pack } => profile::time(Stage::FwdLowrank, || {
                assert_eq!(x.len(), b.rows, "matvec input dim mismatch");
                assert_eq!(y.len(), c.cols, "matvec output dim mismatch");
                match pack {
                    Some((bslot, cslot)) => {
                        let bp = bslot.get_or_init(|| PackedMat::pack(&b.data, b.rows, b.cols));
                        let cp = cslot.get_or_init(|| PackedMat::pack(&c.data, c.rows, c.cols));
                        with_mid_scratch(b.cols, |mid| {
                            vecmat_f32_packed(x, bp, mid);
                            vecmat_f32_packed(mid, cp, y);
                        });
                    }
                    None => {
                        let mid = gemm_f32(x, 1, b.rows, &b.data, b.cols);
                        y.copy_from_slice(&gemm_f32(&mid, 1, c.rows, &c.data, c.cols));
                    }
                }
            }),
        }
    }
}

/// Lazily-packed GEMM panels for one group's factors: the shared basis and
/// each per-layer coefficient block. Mirrors `model::PackRegistry` for the
/// factored representation.
#[derive(Debug, Default)]
struct GroupPack {
    b: OnceLock<PackedMat>,
    cs: Vec<OnceLock<PackedMat>>,
}

/// Shared-basis factors for one group of consecutive layers.
#[derive(Debug)]
pub struct GroupFactors {
    pub start_layer: usize,
    /// shared basis, d1 × k
    pub b: Mat32,
    /// per-layer coefficients, each k × d2 (len == group size n)
    pub cs: Vec<Mat32>,
    /// packed-panel cache (never saved, reset on clone)
    pack: GroupPack,
}

impl Clone for GroupFactors {
    fn clone(&self) -> Self {
        // fresh pack cache: a clone may be mutated before serving
        GroupFactors::new(self.start_layer, self.b.clone(), self.cs.clone())
    }
}

impl GroupFactors {
    pub fn new(start_layer: usize, b: Mat32, cs: Vec<Mat32>) -> Self {
        let pack = GroupPack {
            b: OnceLock::new(),
            cs: (0..cs.len()).map(|_| OnceLock::new()).collect(),
        };
        GroupFactors { start_layer, b, cs, pack }
    }

    pub fn rank(&self) -> usize {
        self.b.cols
    }

    pub fn n_layers(&self) -> usize {
        self.cs.len()
    }

    /// Parameters stored: shared basis once + every coefficient block.
    pub fn param_count(&self) -> usize {
        self.b.rows * self.b.cols
            + self.cs.iter().map(|c| c.rows * c.cols).sum::<usize>()
    }
}

/// Representation of one weight type across all layers.
#[derive(Clone, Debug)]
pub enum TypeRep {
    /// kept dense (rank allocation decided compression isn't worth it)
    Dense,
    Factored(Vec<GroupFactors>),
}

/// A compressed model: original weights + per-type factored replacements.
#[derive(Clone)]
pub struct CompressedModel {
    pub base: Weights,
    pub reps: BTreeMap<String, TypeRep>,
}

impl CompressedModel {
    pub fn dense_passthrough(base: Weights) -> Self {
        let reps = COMPRESSIBLE
            .iter()
            .map(|t| (t.to_string(), TypeRep::Dense))
            .collect();
        Self { base, reps }
    }

    pub fn config(&self) -> ModelConfig {
        self.base.config
    }

    /// Factors of (type, layer) if that type is factored.
    ///
    /// Groups are built in ascending `start_layer` order (the planner walks
    /// `layer_groups` front to back), so the containing group — if any — is
    /// the last one starting at or before `layer`: a binary search, not a
    /// scan.
    pub fn layer_factors(&self, typ: &str, layer: usize) -> Option<(&Mat32, &Mat32)> {
        self.group_at(typ, layer).map(|(g, i)| (&g.b, &g.cs[i]))
    }

    /// The group covering (type, layer) plus the layer's index within it.
    fn group_at(&self, typ: &str, layer: usize) -> Option<(&GroupFactors, usize)> {
        match self.reps.get(typ)? {
            TypeRep::Dense => None,
            TypeRep::Factored(groups) => {
                let i = groups.partition_point(|g| g.start_layer <= layer);
                if i == 0 {
                    return None;
                }
                let g = &groups[i - 1];
                (layer < g.start_layer + g.n_layers())
                    .then(|| (g, layer - g.start_layer))
            }
        }
    }

    /// Resolve the [`Linear`] operator serving (type, layer): the factored
    /// form when this site was compressed, else the dense slab of the base
    /// weight tensor. This is the single seam every pure-Rust projection
    /// call goes through — forward, calibration, eval, and `RefBackend` —
    /// and it hands each site its cached pack slot, so every weight is
    /// packed at most once per model instance no matter how many batches,
    /// workers, or threads serve it.
    pub fn linear(&self, typ: &str, layer: usize) -> Linear<'_> {
        if let Some((g, i)) = self.group_at(typ, layer) {
            return Linear::Factored {
                b: &g.b,
                c: &g.cs[i],
                pack: Some((&g.pack.b, &g.pack.cs[i])),
            };
        }
        let (d1, d2) = self.config().matrix_dims(typ);
        let t = &self.base.tensors[ModelConfig::param_index(typ)];
        Linear::Dense {
            w: &t.data[layer * d1 * d2..(layer + 1) * d1 * d2],
            d1,
            d2,
            pack: Some(self.base.packs.site(typ, layer)),
        }
    }

    /// Number of projection-site pack slots currently holding panels, across
    /// the dense base registry and every factored group (test probe for the
    /// pack-once contract).
    pub fn packed_sites(&self) -> usize {
        let mut n = self.base.packs.packed_sites();
        for rep in self.reps.values() {
            if let TypeRep::Factored(groups) = rep {
                for g in groups {
                    n += usize::from(g.pack.b.get().is_some());
                    n += g.pack.cs.iter().filter(|s| s.get().is_some()).count();
                }
            }
        }
        n
    }

    /// Parameter count across the compressible weight types.
    ///
    /// A factored type may not cover every layer: the compensated pipeline
    /// skips a group whose planned rank hits its break-even point, leaving
    /// those layers dense. They still cost d1·d2 parameters each, so they
    /// are charged at the dense rate — otherwise `achieved_ratio()` would
    /// over-report compression.
    pub fn compressible_param_count(&self) -> usize {
        let cfg = self.config();
        COMPRESSIBLE
            .iter()
            .map(|t| match &self.reps[*t] {
                TypeRep::Dense => {
                    let (d1, d2) = cfg.matrix_dims(t);
                    cfg.layers * d1 * d2
                }
                TypeRep::Factored(groups) => {
                    let (d1, d2) = cfg.matrix_dims(t);
                    let stored: usize = groups.iter().map(|g| g.param_count()).sum();
                    let covered: usize = groups.iter().map(|g| g.n_layers()).sum();
                    stored + (cfg.layers - covered) * d1 * d2
                }
            })
            .sum()
    }

    /// Achieved compression ratio over the compressible weights
    /// (1 − compressed/dense; the paper's θ convention).
    pub fn achieved_ratio(&self) -> f64 {
        let dense = self.config().compressible_params() as f64;
        1.0 - self.compressible_param_count() as f64 / dense
    }

    /// Reconstruct per-layer dense weights W ≈ B·C (for the AOT eval path).
    pub fn to_dense(&self) -> Weights {
        let mut w = self.base.clone();
        for typ in COMPRESSIBLE {
            if let TypeRep::Factored(groups) = &self.reps[typ] {
                let pidx = ModelConfig::param_index(typ);
                for g in groups {
                    for (i, c) in g.cs.iter().enumerate() {
                        let rec = profile::time(Stage::Reconstruct, || matmul_f32(&g.b, c));
                        w.tensors[pidx].set_layer_mat(g.start_layer + i, &rec);
                    }
                }
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny_model() -> CompressedModel {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        CompressedModel::dense_passthrough(Weights::init(cfg, 1))
    }

    #[test]
    fn passthrough_has_zero_ratio() {
        let m = tiny_model();
        assert_eq!(m.achieved_ratio(), 0.0);
        assert_eq!(
            m.compressible_param_count(),
            m.config().compressible_params()
        );
    }

    #[test]
    fn factored_reduces_params_and_reconstructs() {
        let mut m = tiny_model();
        let cfg = m.config();
        let (d1, d2) = cfg.matrix_dims("wq");
        let k = 4usize;
        // factor both layers as one group with an exact rank-k B·C
        let b = Mat32::from_vec(d1, k, (0..d1 * k).map(|i| (i % 7) as f32 * 0.1).collect());
        let cs: Vec<Mat32> = (0..cfg.layers)
            .map(|l| {
                Mat32::from_vec(k, d2, (0..k * d2).map(|i| ((i + l) % 5) as f32 * 0.1).collect())
            })
            .collect();
        m.reps.insert(
            "wq".into(),
            TypeRep::Factored(vec![GroupFactors::new(0, b.clone(), cs.clone())]),
        );
        assert!(m.achieved_ratio() > 0.0);
        let dense = m.to_dense();
        let w0 = dense.by_name("wq").layer_mat(0);
        let want = matmul_f32(&b, &cs[0]);
        assert_eq!(w0.data, want.data);
        // shared basis counted once
        let expect = d1 * k + cfg.layers * k * d2;
        let dense_count = cfg.layers * d1 * d2;
        let total: usize = m.compressible_param_count();
        assert_eq!(
            total,
            cfg.compressible_params() - dense_count + expect
        );
    }

    #[test]
    fn skipped_group_layers_count_as_dense() {
        // a factored type covering only layer 0 of 2: the uncovered layer
        // must be charged at the dense d1*d2 rate, not vanish from the count
        let mut m = tiny_model();
        let cfg = m.config();
        let (d1, d2) = cfg.matrix_dims("wq");
        let k = 4usize;
        let g = GroupFactors::new(0, Mat32::zeros(d1, k), vec![Mat32::zeros(k, d2)]);
        let stored = g.param_count();
        m.reps.insert("wq".into(), TypeRep::Factored(vec![g]));
        let want =
            cfg.compressible_params() - cfg.layers * d1 * d2 // other types dense
            + stored                                         // covered layer 0
            + (cfg.layers - 1) * d1 * d2;                    // uncovered layer 1
        assert_eq!(m.compressible_param_count(), want);
        // ratio reflects only the actually-factored layer
        let expect_ratio =
            1.0 - want as f64 / cfg.compressible_params() as f64;
        assert!((m.achieved_ratio() - expect_ratio).abs() < 1e-12);
    }

    #[test]
    fn layer_factors_lookup() {
        let mut m = tiny_model();
        let cfg = m.config();
        let (d1, d2) = cfg.matrix_dims("wv");
        let g0 = GroupFactors::new(0, Mat32::zeros(d1, 3), vec![Mat32::zeros(3, d2)]);
        let g1 = GroupFactors::new(1, Mat32::zeros(d1, 5), vec![Mat32::zeros(5, d2)]);
        m.reps.insert("wv".into(), TypeRep::Factored(vec![g0, g1]));
        assert_eq!(m.layer_factors("wv", 0).unwrap().0.cols, 3);
        assert_eq!(m.layer_factors("wv", 1).unwrap().0.cols, 5);
        assert!(m.layer_factors("wq", 0).is_none());
    }

    #[test]
    fn layer_factors_handles_gaps_and_uncovered_edges() {
        // groups covering layers {1} and {3} of a 4-layer stack: the binary
        // search must miss layers 0 (before any group), 2 (gap), and 4+
        let cfg = ModelConfig::by_name("s").unwrap();
        let mut m = CompressedModel::dense_passthrough(Weights::init(cfg, 2));
        let (d1, d2) = cfg.matrix_dims("wo");
        let group = |start: usize, k: usize| {
            GroupFactors::new(start, Mat32::zeros(d1, k), vec![Mat32::zeros(k, d2)])
        };
        m.reps.insert("wo".into(), TypeRep::Factored(vec![group(1, 3), group(3, 5)]));
        assert!(m.layer_factors("wo", 0).is_none());
        assert_eq!(m.layer_factors("wo", 1).unwrap().0.cols, 3);
        assert!(m.layer_factors("wo", 2).is_none());
        assert_eq!(m.layer_factors("wo", 3).unwrap().0.cols, 5);
        assert!(m.layer_factors("wo", 4).is_none());
    }

    #[test]
    fn linear_resolves_dense_slab_and_factored_sites() {
        let mut m = tiny_model();
        let cfg = m.config();
        let (d1, d2) = cfg.matrix_dims("wq");
        // dense site: slab must alias the base tensor's layer-1 window
        match m.linear("wq", 1) {
            Linear::Dense { w, d1: a, d2: b, pack } => {
                assert_eq!((a, b), (d1, d2));
                assert_eq!(w, &m.base.by_name("wq").data[d1 * d2..2 * d1 * d2]);
                assert!(pack.is_some(), "model-resolved site must carry a pack slot");
            }
            Linear::Factored { .. } => panic!("passthrough resolved factored"),
        }
        let k = 4usize;
        let b = Mat32::from_vec(d1, k, (0..d1 * k).map(|i| (i % 9) as f32 * 0.01).collect());
        let cs: Vec<Mat32> = (0..cfg.layers)
            .map(|l| Mat32::from_vec(k, d2, (0..k * d2).map(|i| ((i + l) % 6) as f32 * 0.01).collect()))
            .collect();
        m.reps.insert(
            "wq".into(),
            TypeRep::Factored(vec![GroupFactors::new(0, b, cs)]),
        );
        assert!(matches!(m.linear("wq", 0), Linear::Factored { .. }));
        assert_eq!(m.linear("wq", 0).dims(), (d1, d2));
    }

    #[test]
    fn linear_matmul_factored_matches_dense_reconstruction() {
        // (x·B)·C vs x·(B·C): same product up to f32 rounding of the
        // intermediate — the exact equivalence the serving path relies on
        let (d1, k, d2, rows) = (24usize, 5usize, 16usize, 7usize);
        let b = Mat32::from_vec(d1, k, (0..d1 * k).map(|i| ((i % 11) as f32 - 5.0) * 0.02).collect());
        let c = Mat32::from_vec(k, d2, (0..k * d2).map(|i| ((i % 7) as f32 - 3.0) * 0.03).collect());
        let x: Vec<f32> = (0..rows * d1).map(|i| ((i % 13) as f32 - 6.0) * 0.05).collect();
        let factored = Linear::Factored { b: &b, c: &c, pack: None }.matmul(&x, rows);
        let w = matmul_f32(&b, &c);
        let dense = Linear::Dense { w: &w.data, d1, d2, pack: None }.matmul(&x, rows);
        assert_eq!(factored.len(), rows * d2);
        for (f, d) in factored.iter().zip(&dense) {
            assert!((f - d).abs() < 1e-4, "{f} vs {d}");
        }
    }

    #[test]
    fn matvec_is_byte_identical_to_one_row_matmul() {
        // the decode kernel must agree bitwise with the batched path on the
        // same row, for both representations, packed and unpacked
        let (d1, k, d2) = (33usize, 6usize, 40usize);
        let b = Mat32::from_vec(d1, k, (0..d1 * k).map(|i| ((i % 11) as f32 - 5.0) * 0.02).collect());
        let c = Mat32::from_vec(k, d2, (0..k * d2).map(|i| ((i % 7) as f32 - 3.0) * 0.03).collect());
        let x: Vec<f32> = (0..d1).map(|i| ((i % 13) as f32 - 6.0) * 0.05).collect();
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();

        let fslot = (OnceLock::new(), OnceLock::new());
        let fac = Linear::Factored { b: &b, c: &c, pack: Some((&fslot.0, &fslot.1)) };
        let want = fac.matmul(&x, 1);
        let mut got = vec![f32::NAN; d2];
        fac.matvec(&x, &mut got);
        assert_eq!(bits(&got), bits(&want), "factored matvec != 1-row matmul");
        let mut unpacked = vec![f32::NAN; d2];
        Linear::Factored { b: &b, c: &c, pack: None }.matvec(&x, &mut unpacked);
        assert_eq!(bits(&unpacked), bits(&want), "unpacked factored matvec");

        let w = matmul_f32(&b, &c);
        let dslot = OnceLock::new();
        let den = Linear::Dense { w: &w.data, d1, d2, pack: Some(&dslot) };
        let dwant = den.matmul(&x, 1);
        let mut dgot = vec![f32::NAN; d2];
        den.matvec(&x, &mut dgot);
        assert_eq!(bits(&dgot), bits(&dwant), "dense matvec != 1-row matmul");
        let mut dplain = vec![f32::NAN; d2];
        Linear::Dense { w: &w.data, d1, d2, pack: None }.matvec(&x, &mut dplain);
        assert_eq!(bits(&dplain), bits(&dwant), "unpacked dense matvec");
    }

    #[test]
    fn packed_linear_is_byte_identical_to_unpacked() {
        // the same site executed with and without its pack slot must agree
        // to the bit, for both representations
        let (d1, k, d2, rows) = (33usize, 6usize, 40usize, 9usize);
        let b = Mat32::from_vec(d1, k, (0..d1 * k).map(|i| ((i % 11) as f32 - 5.0) * 0.02).collect());
        let c = Mat32::from_vec(k, d2, (0..k * d2).map(|i| ((i % 7) as f32 - 3.0) * 0.03).collect());
        let x: Vec<f32> = (0..rows * d1).map(|i| ((i % 13) as f32 - 6.0) * 0.05).collect();
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();

        let fslot = (OnceLock::new(), OnceLock::new());
        let unfused = Linear::Factored { b: &b, c: &c, pack: None }.matmul(&x, rows);
        let fused =
            Linear::Factored { b: &b, c: &c, pack: Some((&fslot.0, &fslot.1)) }.matmul(&x, rows);
        assert_eq!(bits(&fused), bits(&unfused));

        let w = matmul_f32(&b, &c);
        let dslot = OnceLock::new();
        let plain = Linear::Dense { w: &w.data, d1, d2, pack: None }.matmul(&x, rows);
        let packed =
            Linear::Dense { w: &w.data, d1, d2, pack: Some(&dslot) }.matmul(&x, rows);
        assert_eq!(bits(&packed), bits(&plain));
    }
}
