//! Compressed model representation: per-type dense or factored weights.
//!
//! A factored type stores per *group* a shared basis B (d1×k_g) and
//! per-layer coefficients C⁽ⁱ⁾ (k_g×d2) — the Basis-Sharing layout the
//! paper builds on (n=1 groups degenerate to plain SVD-LLM factors).
//!
//! Two execution paths consume this:
//!  - `to_dense()` reconstructs W ≈ B·C per layer and reuses the AOT dense
//!    artifact (bit-accurate PPL/zero-shot evaluation, no recompilation);
//!  - `graph::build_compressed` emits the *factored* matmuls with the exact
//!    allocated ranks for the runtime throughput path.

use std::collections::BTreeMap;

use super::{ModelConfig, Weights, COMPRESSIBLE};
use crate::tensor::{matmul::matmul_f32, Mat32};
use crate::util::profile::{self, Stage};

/// Shared-basis factors for one group of consecutive layers.
#[derive(Clone, Debug)]
pub struct GroupFactors {
    pub start_layer: usize,
    /// shared basis, d1 × k
    pub b: Mat32,
    /// per-layer coefficients, each k × d2 (len == group size n)
    pub cs: Vec<Mat32>,
}

impl GroupFactors {
    pub fn rank(&self) -> usize {
        self.b.cols
    }

    pub fn n_layers(&self) -> usize {
        self.cs.len()
    }

    /// Parameters stored: shared basis once + every coefficient block.
    pub fn param_count(&self) -> usize {
        self.b.rows * self.b.cols
            + self.cs.iter().map(|c| c.rows * c.cols).sum::<usize>()
    }
}

/// Representation of one weight type across all layers.
#[derive(Clone, Debug)]
pub enum TypeRep {
    /// kept dense (rank allocation decided compression isn't worth it)
    Dense,
    Factored(Vec<GroupFactors>),
}

/// A compressed model: original weights + per-type factored replacements.
#[derive(Clone)]
pub struct CompressedModel {
    pub base: Weights,
    pub reps: BTreeMap<String, TypeRep>,
}

impl CompressedModel {
    pub fn dense_passthrough(base: Weights) -> Self {
        let reps = COMPRESSIBLE
            .iter()
            .map(|t| (t.to_string(), TypeRep::Dense))
            .collect();
        Self { base, reps }
    }

    pub fn config(&self) -> ModelConfig {
        self.base.config
    }

    /// Factors of (type, layer) if that type is factored.
    pub fn layer_factors(&self, typ: &str, layer: usize) -> Option<(&Mat32, &Mat32)> {
        match self.reps.get(typ)? {
            TypeRep::Dense => None,
            TypeRep::Factored(groups) => {
                for g in groups {
                    if layer >= g.start_layer && layer < g.start_layer + g.n_layers() {
                        return Some((&g.b, &g.cs[layer - g.start_layer]));
                    }
                }
                None
            }
        }
    }

    /// Parameter count across the compressible weight types.
    ///
    /// A factored type may not cover every layer: the compensated pipeline
    /// skips a group whose planned rank hits its break-even point, leaving
    /// those layers dense. They still cost d1·d2 parameters each, so they
    /// are charged at the dense rate — otherwise `achieved_ratio()` would
    /// over-report compression.
    pub fn compressible_param_count(&self) -> usize {
        let cfg = self.config();
        COMPRESSIBLE
            .iter()
            .map(|t| match &self.reps[*t] {
                TypeRep::Dense => {
                    let (d1, d2) = cfg.matrix_dims(t);
                    cfg.layers * d1 * d2
                }
                TypeRep::Factored(groups) => {
                    let (d1, d2) = cfg.matrix_dims(t);
                    let stored: usize = groups.iter().map(|g| g.param_count()).sum();
                    let covered: usize = groups.iter().map(|g| g.n_layers()).sum();
                    stored + (cfg.layers - covered) * d1 * d2
                }
            })
            .sum()
    }

    /// Achieved compression ratio over the compressible weights
    /// (1 − compressed/dense; the paper's θ convention).
    pub fn achieved_ratio(&self) -> f64 {
        let dense = self.config().compressible_params() as f64;
        1.0 - self.compressible_param_count() as f64 / dense
    }

    /// Reconstruct per-layer dense weights W ≈ B·C (for the AOT eval path).
    pub fn to_dense(&self) -> Weights {
        let mut w = self.base.clone();
        let cfg = self.config();
        for typ in COMPRESSIBLE {
            if let TypeRep::Factored(groups) = &self.reps[typ] {
                let pidx = ModelConfig::param_index(typ);
                for g in groups {
                    for (i, c) in g.cs.iter().enumerate() {
                        let rec = profile::time(Stage::Reconstruct, || matmul_f32(&g.b, c));
                        w.tensors[pidx].set_layer_mat(g.start_layer + i, &rec);
                    }
                }
                let _ = cfg;
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny_model() -> CompressedModel {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        CompressedModel::dense_passthrough(Weights::init(cfg, 1))
    }

    #[test]
    fn passthrough_has_zero_ratio() {
        let m = tiny_model();
        assert_eq!(m.achieved_ratio(), 0.0);
        assert_eq!(
            m.compressible_param_count(),
            m.config().compressible_params()
        );
    }

    #[test]
    fn factored_reduces_params_and_reconstructs() {
        let mut m = tiny_model();
        let cfg = m.config();
        let (d1, d2) = cfg.matrix_dims("wq");
        let k = 4usize;
        // factor both layers as one group with an exact rank-k B·C
        let b = Mat32::from_vec(d1, k, (0..d1 * k).map(|i| (i % 7) as f32 * 0.1).collect());
        let cs: Vec<Mat32> = (0..cfg.layers)
            .map(|l| {
                Mat32::from_vec(k, d2, (0..k * d2).map(|i| ((i + l) % 5) as f32 * 0.1).collect())
            })
            .collect();
        m.reps.insert(
            "wq".into(),
            TypeRep::Factored(vec![GroupFactors { start_layer: 0, b: b.clone(), cs: cs.clone() }]),
        );
        assert!(m.achieved_ratio() > 0.0);
        let dense = m.to_dense();
        let w0 = dense.by_name("wq").layer_mat(0);
        let want = matmul_f32(&b, &cs[0]);
        assert_eq!(w0.data, want.data);
        // shared basis counted once
        let expect = d1 * k + cfg.layers * k * d2;
        let dense_count = cfg.layers * d1 * d2;
        let total: usize = m.compressible_param_count();
        assert_eq!(
            total,
            cfg.compressible_params() - dense_count + expect
        );
    }

    #[test]
    fn skipped_group_layers_count_as_dense() {
        // a factored type covering only layer 0 of 2: the uncovered layer
        // must be charged at the dense d1*d2 rate, not vanish from the count
        let mut m = tiny_model();
        let cfg = m.config();
        let (d1, d2) = cfg.matrix_dims("wq");
        let k = 4usize;
        let g = GroupFactors {
            start_layer: 0,
            b: Mat32::zeros(d1, k),
            cs: vec![Mat32::zeros(k, d2)],
        };
        let stored = g.param_count();
        m.reps.insert("wq".into(), TypeRep::Factored(vec![g]));
        let want =
            cfg.compressible_params() - cfg.layers * d1 * d2 // other types dense
            + stored                                         // covered layer 0
            + (cfg.layers - 1) * d1 * d2;                    // uncovered layer 1
        assert_eq!(m.compressible_param_count(), want);
        // ratio reflects only the actually-factored layer
        let expect_ratio =
            1.0 - want as f64 / cfg.compressible_params() as f64;
        assert!((m.achieved_ratio() - expect_ratio).abs() < 1e-12);
    }

    #[test]
    fn layer_factors_lookup() {
        let mut m = tiny_model();
        let cfg = m.config();
        let (d1, d2) = cfg.matrix_dims("wv");
        let g0 = GroupFactors {
            start_layer: 0,
            b: Mat32::zeros(d1, 3),
            cs: vec![Mat32::zeros(3, d2)],
        };
        let g1 = GroupFactors {
            start_layer: 1,
            b: Mat32::zeros(d1, 5),
            cs: vec![Mat32::zeros(5, d2)],
        };
        m.reps.insert("wv".into(), TypeRep::Factored(vec![g0, g1]));
        assert_eq!(m.layer_factors("wv", 0).unwrap().0.cols, 3);
        assert_eq!(m.layer_factors("wv", 1).unwrap().0.cols, 5);
        assert!(m.layer_factors("wq", 0).is_none());
    }
}
