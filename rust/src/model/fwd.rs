//! Pure-Rust reference forward pass (test oracle + serving backend).
//!
//! Functionally a port of `python/compile/model.py`, used to cross-check
//! the AOT artifacts and the runtime-built XLA graphs at tiny sizes, to
//! back the coordinator's artifact-free `RefBackend`, and — via the
//! [`CalibSums`] observer — to collect calibration statistics without the
//! PJRT `calib` artifact.
//!
//! Execution is batched, not scalar: every projection site resolves to a
//! [`Linear`] operator and runs as a row-band-parallel GEMM over all
//! `batch·t` activation rows at once (`tensor::matmul::gemm_f32` on the
//! `util::parallel` pool). The same forward therefore serves *dense*
//! weights ([`nll`]) and *factored* compressed models ([`nll_model`]) —
//! a factored site executes `(x·B)·C` directly and never rematerializes
//! the dense weight. Per-row floating-point order is independent of the
//! band split, so all outputs are bit-identical for any thread count
//! (enforced by `rust/tests/forward_equivalence.rs`).

use super::lowrank::{CompressedModel, Linear};
use super::{ModelConfig, Weights};
use crate::tensor::matmul::{gemm_f32_packed_serial, PackedMat};
use crate::tensor::MatF;
use crate::util::parallel::parallel_row_bands;
use crate::util::profile::{self, Stage};

const EPS: f32 = 1e-5;
const ROPE_THETA: f32 = 1e4;

// Streaming-softmax attention tiles: TQ query rows share each loaded
// key/value tile of TK rows. Sized so one (TQ·hd + 2·TK·hd) working set
// stays L1-resident at every config's head_dim.
const ATTN_TQ: usize = 16;
const ATTN_TK: usize = 32;

// Fused lm_head/cross-entropy chunk: rows of logits materialized at once
// per band thread (peak logits memory = threads · NLL_CHUNK · vocab, not
// batch·seq · vocab).
const NLL_CHUNK: usize = 16;

// Calibration slots (must mirror `calib::gram_slot`):
// 0 = input to wq/wk/wv, 1 = input to wo, 2 = input to w_gate/w_up,
// 3 = input to w_down.
const SLOT_ATTN: usize = 0;
const SLOT_O: usize = 1;
const SLOT_MLP: usize = 2;
const SLOT_DOWN: usize = 3;

/// Parameter source for one forward pass: plain dense weights or a
/// compressed model whose factored sites run on their factors. All the
/// block code below is written against this, so dense and factored
/// execution share every instruction except the [`Linear::matmul`]
/// dispatch.
#[derive(Clone, Copy)]
enum Params<'a> {
    Dense(&'a Weights),
    Model(&'a CompressedModel),
}

impl<'a> Params<'a> {
    fn weights(&self) -> &'a Weights {
        match self {
            Params::Dense(w) => w,
            Params::Model(m) => &m.base,
        }
    }

    /// The [`Linear`] operator serving (type, layer).
    fn linear(&self, typ: &str, l: usize) -> Linear<'a> {
        match self {
            Params::Dense(w) => {
                let (d1, d2) = w.config.matrix_dims(typ);
                let t = &w.tensors[ModelConfig::param_index(typ)];
                Linear::Dense {
                    w: &t.data[l * d1 * d2..(l + 1) * d1 * d2],
                    d1,
                    d2,
                    pack: Some(w.packs.site(typ, l)),
                }
            }
            Params::Model(m) => m.linear(typ, l),
        }
    }

    /// The lm_head's packed panels (packed once per model instance; the
    /// lm_head is never compressed, so both variants use the base registry).
    fn lm_packed(&self) -> &'a PackedMat {
        let w = self.weights();
        let lm = w.by_name("lm_head");
        let (d, v) = (w.config.d, w.config.vocab);
        w.packs.lm_head().get_or_init(|| PackedMat::pack(&lm.data, d, v))
    }
}

/// Raw calibration sums accumulated by the instrumented forward:
/// un-normalized Σ x·xᵀ per (slot, layer) and Σ|x| per (slot, layer, dim),
/// matching the wire semantics of the AOT `calib` artifact (the caller
/// normalizes by total tokens, exactly like `calib::run`).
pub struct CalibSums {
    pub grams: Vec<Vec<MatF>>,
    pub absmean: Vec<Vec<Vec<f64>>>,
    pub tokens: usize,
}

impl CalibSums {
    pub fn new(cfg: &ModelConfig) -> Self {
        let slot_dim = [cfg.d, cfg.d, cfg.d, cfg.dff];
        Self {
            grams: slot_dim
                .iter()
                .map(|&d| (0..cfg.layers).map(|_| MatF::zeros(d, d)).collect())
                .collect(),
            absmean: slot_dim.iter().map(|&d| vec![vec![0.0; d]; cfg.layers]).collect(),
            tokens: 0,
        }
    }

    /// Accumulate one projection-input vector into (slot, layer).
    fn record(&mut self, slot: usize, layer: usize, x: &[f32]) {
        let d = x.len();
        let g = &mut self.grams[slot][layer];
        debug_assert_eq!(g.rows, d);
        for i in 0..d {
            let xi = x[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let row = &mut g.data[i * d..(i + 1) * d];
            for (j, rj) in row.iter_mut().enumerate() {
                *rj += xi * x[j] as f64;
            }
        }
        let am = &mut self.absmean[slot][layer];
        for i in 0..d {
            am[i] += x[i].abs() as f64;
        }
    }

    /// Accumulate every row of a `rows`×`d` activation buffer, in row
    /// order (b-major, position-minor — the order the scalar forward
    /// recorded in, so sums stay bit-identical to the historical path).
    fn record_rows(&mut self, slot: usize, layer: usize, x: &[f32], d: usize) {
        for row in x.chunks_exact(d) {
            self.record(slot, layer, row);
        }
    }

    /// Fold another accumulator into this one (elementwise sums). The
    /// parallel calibration path computes one `CalibSums` per batch and
    /// merges them in batch order, so results don't depend on thread count.
    pub fn merge(&mut self, other: &CalibSums) {
        for slot in 0..self.grams.len() {
            for l in 0..self.grams[slot].len() {
                self.grams[slot][l].add_assign(&other.grams[slot][l]);
                for (a, b) in
                    self.absmean[slot][l].iter_mut().zip(&other.absmean[slot][l])
                {
                    *a += b;
                }
            }
        }
        self.tokens += other.tokens;
    }
}

/// Run the reference forward over one `[batch, seq]` token window while
/// accumulating calibration statistics into `sums` (the artifact-free twin
/// of streaming a batch through the AOT `calib` artifact).
pub fn accumulate_calib(
    w: &Weights,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    sums: &mut CalibSums,
) {
    // the AOT calib artifact embeds the full [B, S] window (no next-token
    // trim), so statistics cover all `seq` positions — mirror that exactly
    let _ = forward_hidden_obs(Params::Dense(w), tokens, batch, seq, seq, Some(sums));
    sums.tokens += batch * seq;
}

/// [`accumulate_calib`] over a compressed model: factored sites run on
/// their factors, so compensated recalibration observes the compressed
/// network without reconstructing dense weights.
pub fn accumulate_calib_model(
    m: &CompressedModel,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    sums: &mut CalibSums,
) {
    let _ = forward_hidden_obs(Params::Model(m), tokens, batch, seq, seq, Some(sums));
    sums.tokens += batch * seq;
}

/// Per-token NLL for a [batch, seq] token matrix; returns [batch, seq-1].
pub fn nll(w: &Weights, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
    nll_impl(Params::Dense(w), tokens, batch, seq)
}

/// [`nll`] over a compressed model, consuming factored weights directly —
/// the serving path for `RefBackend`'s factored mode, `eval::ppl_reference`,
/// and the factored-vs-dense equivalence suite.
pub fn nll_model(m: &CompressedModel, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
    nll_impl(Params::Model(m), tokens, batch, seq)
}

fn nll_impl(p: Params<'_>, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
    let cfg = p.weights().config;
    let t = seq - 1;
    let rows = batch * t;
    let hidden = forward_hidden_obs(p, tokens, batch, seq, t, None);
    // fused lm_head projection + cross entropy: each band thread projects
    // its rows in NLL_CHUNK-row chunks through the packed lm_head panels
    // into a small logits scratch and consumes it immediately, so the
    // rows×V logits slab is never materialized. Chunked serial GEMM keeps
    // every logit's FP order identical to the one-big-GEMM path (the packed
    // kernel's accumulation order per output element is row-local).
    let (d, v) = (cfg.d, cfg.vocab);
    let lmp = p.lm_packed();
    let mut out = vec![0.0f32; rows];
    profile::time(Stage::Fwd, || {
        parallel_row_bands(&mut out, rows, 1, |row0, band| {
            let mut logits = vec![0.0f32; NLL_CHUNK * v];
            let mut r0 = row0;
            for chunk in band.chunks_mut(NLL_CHUNK) {
                let bn = chunk.len();
                let lbuf = &mut logits[..bn * v];
                gemm_f32_packed_serial(&hidden[r0 * d..(r0 + bn) * d], bn, d, lmp, lbuf);
                for (i, o) in chunk.iter_mut().enumerate() {
                    let r = r0 + i;
                    let row = &lbuf[i * v..(i + 1) * v];
                    let max = row.iter().cloned().fold(f32::MIN, f32::max);
                    let logz = max + row.iter().map(|x| (x - max).exp()).sum::<f32>().ln();
                    let (b, pos) = (r / t, r % t);
                    let target = tokens[b * seq + pos + 1] as usize;
                    *o = logz - row[target];
                }
                r0 += bn;
            }
        });
    });
    out
}

/// Final normed hidden states for inputs tokens[:, :t]; [batch*t*d].
pub fn forward_hidden(
    w: &Weights,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    t: usize,
) -> Vec<f32> {
    forward_hidden_obs(Params::Dense(w), tokens, batch, seq, t, None)
}

/// Forward with an optional calibration observer hooked on the inputs of
/// every compressible projection.
fn forward_hidden_obs(
    p: Params<'_>,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    t: usize,
    mut sums: Option<&mut CalibSums>,
) -> Vec<f32> {
    let cfg = p.weights().config;
    let d = cfg.d;
    let embed = p.weights().by_name("embed");
    let mut x = vec![0.0f32; batch * t * d];
    for b in 0..batch {
        for pos in 0..t {
            let tok = tokens[b * seq + pos] as usize;
            x[(b * t + pos) * d..(b * t + pos + 1) * d]
                .copy_from_slice(&embed.data[tok * d..(tok + 1) * d]);
        }
    }
    let (cos, sin) = rope_tables(t, cfg.head_dim());
    for l in 0..cfg.layers {
        attention_block(p, &mut x, batch, t, l, &cos, &sin, sums.as_deref_mut());
        mlp_block(p, &mut x, batch, t, l, sums.as_deref_mut());
    }
    // final rmsnorm, row-parallel
    let fnorm = &p.weights().by_name("final_norm").data;
    parallel_row_bands(&mut x, batch * t, d, |_, band| {
        for row in band.chunks_exact_mut(d) {
            rmsnorm_inplace(row, fnorm);
        }
    });
    x
}

fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + EPS).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

fn rmsnorm_inplace(x: &mut [f32], w: &[f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + EPS).sqrt();
    for i in 0..x.len() {
        x[i] *= inv * w[i];
    }
}

/// Normalize every row of `x` into a fresh buffer, row-parallel.
fn rmsnorm_rows(x: &[f32], w: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    parallel_row_bands(&mut out, rows, d, |row0, band| {
        for (i, orow) in band.chunks_exact_mut(d).enumerate() {
            let r = row0 + i;
            rmsnorm(&x[r * d..(r + 1) * d], w, orow);
        }
    });
    out
}

/// y += o, elementwise over the residual stream, row-parallel.
fn residual_add(x: &mut [f32], o: &[f32], rows: usize, d: usize) {
    parallel_row_bands(x, rows, d, |row0, band| {
        let base = row0 * d;
        for (i, xv) in band.iter_mut().enumerate() {
            *xv += o[base + i];
        }
    });
}

fn rope_tables(t: usize, hd: usize) -> (Vec<f32>, Vec<f32>) {
    let half = hd / 2;
    // the frequency depends only on the lane, not the position: compute the
    // `half` powf calls once instead of t×half times
    let freqs: Vec<f32> =
        (0..half).map(|i| ROPE_THETA.powf(-(i as f32) / half as f32)).collect();
    let mut cos = vec![0.0f32; t * half];
    let mut sin = vec![0.0f32; t * half];
    for p in 0..t {
        for (i, &freq) in freqs.iter().enumerate() {
            let ang = p as f32 * freq;
            cos[p * half + i] = ang.cos();
            sin[p * half + i] = ang.sin();
        }
    }
    (cos, sin)
}

/// rotate-half rope on one head vector at position p.
fn apply_rope(v: &mut [f32], p: usize, cos: &[f32], sin: &[f32]) {
    let half = v.len() / 2;
    for i in 0..half {
        let c = cos[p * half + i];
        let s = sin[p * half + i];
        let x1 = v[i];
        let x2 = v[half + i];
        v[i] = x1 * c - x2 * s;
        v[half + i] = x2 * c + x1 * s;
    }
}

/// Blocked streaming-softmax attention over roped q/k/v buffers.
///
/// Work units are (batch, head) pairs; the output is head-major,
/// `batch·h` rows of `t·hd` — one contiguous band per unit, so
/// `parallel_row_bands` hands each thread whole units. Within a unit,
/// query rows are processed in tiles of [`ATTN_TQ`] and keys/values in
/// tiles of [`ATTN_TK`] (flash-attention style): each query keeps a running
/// max `m`, denominator `l`, and unnormalized accumulator; when a tile
/// raises the max, the accumulator and denominator are rescaled by
/// `exp(m_old − m_new)` once, and the final division by `l` normalizes.
///
/// Determinism: for every output element the FP op sequence is a pure
/// function of (t, hd, the tile constants) — tiles run in ascending key
/// order and the thread split only chooses *which* units a thread runs,
/// never the op order inside one. Hence 1/2/4-thread outputs are
/// `to_bits`-identical (`rust/tests/determinism.rs`), and the kernel
/// matches the exact two-pass softmax to ~1e-7 (pinned at 1e-5 against the
/// scalar oracle in `rust/tests/forward_equivalence.rs`).
#[allow(clippy::too_many_arguments)]
fn attention_streaming(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    batch: usize,
    t: usize,
    kvd: usize,
    h: usize,
    rep: usize,
    hd: usize,
    scale: f32,
) -> Vec<f32> {
    let d = h * hd;
    let units = batch * h;
    let mut hm = vec![0.0f32; units * t * hd];
    parallel_row_bands(&mut hm, units, t * hd, |u0, band| {
        let mut scores = [0.0f32; ATTN_TK];
        let mut mrow = [f32::MIN; ATTN_TQ]; // running max per query
        let mut lrow = [0.0f32; ATTN_TQ]; // running denominator per query
        for (ui, ub) in band.chunks_exact_mut(t * hd).enumerate() {
            let u = u0 + ui;
            let (b, head) = (u / h, u % h);
            let kv_head = head / rep;
            for q0 in (0..t).step_by(ATTN_TQ) {
                let q1 = (q0 + ATTN_TQ).min(t);
                mrow[..q1 - q0].fill(f32::MIN);
                lrow[..q1 - q0].fill(0.0);
                // causal: keys 0..q1 suffice for every query in the tile
                for k0 in (0..q1).step_by(ATTN_TK) {
                    let k1 = (k0 + ATTN_TK).min(q1);
                    // queries before k0 see nothing of this tile
                    for qi in q0.max(k0)..q1 {
                        let kend = k1.min(qi + 1);
                        let qv = &q[(b * t + qi) * d + head * hd..][..hd];
                        let mut tmax = f32::MIN;
                        for j in k0..kend {
                            let kv = &k[(b * t + j) * kvd + kv_head * hd..][..hd];
                            let s =
                                qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                            scores[j - k0] = s;
                            tmax = tmax.max(s);
                        }
                        let mi = qi - q0;
                        let acc = &mut ub[qi * hd..(qi + 1) * hd];
                        if tmax > mrow[mi] {
                            // rescale history to the new max (first tile has
                            // no history: lrow is 0 and acc is all zeros)
                            if lrow[mi] > 0.0 {
                                let corr = (mrow[mi] - tmax).exp();
                                for a in acc.iter_mut() {
                                    *a *= corr;
                                }
                                lrow[mi] *= corr;
                            }
                            mrow[mi] = tmax;
                        }
                        for j in k0..kend {
                            let pj = (scores[j - k0] - mrow[mi]).exp();
                            lrow[mi] += pj;
                            let vv = &v[(b * t + j) * kvd + kv_head * hd..][..hd];
                            for (a, &vx) in acc.iter_mut().zip(vv) {
                                *a += pj * vx;
                            }
                        }
                    }
                }
                for qi in q0..q1 {
                    let inv = 1.0 / lrow[qi - q0];
                    for a in &mut ub[qi * hd..(qi + 1) * hd] {
                        *a *= inv;
                    }
                }
            }
        }
    });
    hm
}

#[allow(clippy::too_many_arguments)]
fn attention_block(
    p: Params<'_>,
    x: &mut [f32],
    batch: usize,
    t: usize,
    l: usize,
    cos: &[f32],
    sin: &[f32],
    mut sums: Option<&mut CalibSums>,
) {
    let w = p.weights();
    let cfg = w.config;
    let (d, h, kvh, hd) = (cfg.d, cfg.heads, cfg.kv_heads, cfg.head_dim());
    let kvd = cfg.kvd();
    let an = &w.by_name("attn_norm").data[l * d..(l + 1) * d];
    let rep = h / kvh;
    let scale = 1.0 / (hd as f32).sqrt();
    let rows = batch * t;

    // pre-projection norm over every row, then one GEMM per projection
    let xn = rmsnorm_rows(x, an, rows, d);
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_ATTN, l, &xn, d);
    }
    let mut q = p.linear("wq", l).matmul(&xn, rows);
    let mut k = p.linear("wk", l).matmul(&xn, rows);
    let v = p.linear("wv", l).matmul(&xn, rows);
    // rope, row-parallel (a row's position is r % t)
    parallel_row_bands(&mut q, rows, d, |row0, band| {
        for (i, row) in band.chunks_exact_mut(d).enumerate() {
            let pos = (row0 + i) % t;
            for head in 0..h {
                apply_rope(&mut row[head * hd..(head + 1) * hd], pos, cos, sin);
            }
        }
    });
    parallel_row_bands(&mut k, rows, kvd, |row0, band| {
        for (i, row) in band.chunks_exact_mut(kvd).enumerate() {
            let pos = (row0 + i) % t;
            for head in 0..kvh {
                apply_rope(&mut row[head * hd..(head + 1) * hd], pos, cos, sin);
            }
        }
    });
    // blocked streaming-softmax attention (flash-style): head-major units
    // fan out across threads, each unit runs key/value tiles with a running
    // max/denominator; then a deterministic transpose back to row-major.
    // Profiled as its own `attn` stage (it is not a GEMM).
    let attn = profile::time(Stage::Attn, || {
        let hm = attention_streaming(&q, &k, &v, batch, t, kvd, h, rep, hd, scale);
        let mut attn = vec![0.0f32; rows * d];
        parallel_row_bands(&mut attn, rows, d, |row0, band| {
            for (i, row) in band.chunks_exact_mut(d).enumerate() {
                let r = row0 + i;
                let (b, pos) = (r / t, r % t);
                for head in 0..h {
                    let src = &hm[((b * h + head) * t + pos) * hd..][..hd];
                    row[head * hd..(head + 1) * hd].copy_from_slice(src);
                }
            }
        });
        attn
    });
    // output projection + residual
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_O, l, &attn, d);
    }
    let o = p.linear("wo", l).matmul(&attn, rows);
    residual_add(x, &o, rows, d);
}

fn mlp_block(
    p: Params<'_>,
    x: &mut [f32],
    batch: usize,
    t: usize,
    l: usize,
    mut sums: Option<&mut CalibSums>,
) {
    let w = p.weights();
    let cfg = w.config;
    let (d, dff) = (cfg.d, cfg.dff);
    let mn = &w.by_name("mlp_norm").data[l * d..(l + 1) * d];
    let rows = batch * t;

    let xn = rmsnorm_rows(x, mn, rows, d);
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_MLP, l, &xn, d);
    }
    let mut g = p.linear("w_gate", l).matmul(&xn, rows);
    let u = p.linear("w_up", l).matmul(&xn, rows);
    // silu(g) * u, elementwise row-parallel
    parallel_row_bands(&mut g, rows, dff, |row0, band| {
        let base = row0 * dff;
        for (i, gv) in band.iter_mut().enumerate() {
            let s = *gv / (1.0 + (-*gv).exp());
            *gv = s * u[base + i];
        }
    });
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_DOWN, l, &g, dff);
    }
    let o = p.linear("w_down", l).matmul(&g, rows);
    residual_add(x, &o, rows, d);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lowrank::CompressedModel;
    use crate::model::{ModelConfig, Weights};
    use crate::util::rng::Rng;

    fn setup() -> (Weights, Vec<i32>, usize, usize) {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 3);
        let mut r = Rng::new(5);
        let (b, s) = (cfg.batch, cfg.seq);
        let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
        (w, toks, b, s)
    }

    #[test]
    fn nll_near_uniform_for_random_model() {
        let (w, toks, b, s) = setup();
        let out = nll(&w, &toks, b, s);
        assert_eq!(out.len(), b * (s - 1));
        let mean = out.iter().sum::<f32>() / out.len() as f32;
        let want = (w.config.vocab as f32).ln();
        assert!((mean - want).abs() < 1.0, "mean {mean} vs ln(V) {want}");
    }

    #[test]
    fn causality_future_tokens_do_not_matter() {
        let (w, mut toks, b, s) = setup();
        let a = nll(&w, &toks, b, s);
        // change the last token; all positions except the final prediction
        // target must be unaffected
        toks[s - 1] = (toks[s - 1] + 1).rem_euclid(w.config.vocab as i32);
        let c = nll(&w, &toks, b, s);
        let t = s - 1;
        for pos in 0..t - 1 {
            assert!((a[pos] - c[pos]).abs() < 1e-5, "pos {pos}");
        }
        assert!((a[t - 1] - c[t - 1]).abs() > 1e-7); // target changed
    }

    #[test]
    fn calib_sums_are_symmetric_and_positive() {
        let (w, toks, b, s) = setup();
        let mut sums = CalibSums::new(&w.config);
        accumulate_calib(&w, &toks, b, s, &mut sums);
        accumulate_calib(&w, &toks, b, s, &mut sums);
        assert_eq!(sums.tokens, 2 * b * s);
        for slot in 0..4 {
            let g = &sums.grams[slot][0];
            for i in 0..g.rows {
                assert!(g.at(i, i) >= 0.0);
                for j in 0..g.cols {
                    assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-6, "slot {slot} ({i},{j})");
                }
            }
        }
        assert_eq!(sums.grams[3][0].rows, w.config.dff);
        assert!(sums.absmean[0][0].iter().all(|&v| v >= 0.0));
        // the observer must not perturb the forward itself
        let plain = nll(&w, &toks, b, s);
        let again = nll(&w, &toks, b, s);
        assert_eq!(plain, again);
    }

    #[test]
    fn gqa_runs_and_is_finite() {
        let cfg = ModelConfig::by_name("gqa").unwrap();
        let w = Weights::init(cfg, 4);
        let mut r = Rng::new(6);
        let (b, s) = (1, 16);
        let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
        let out = nll(&w, &toks, b, s);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn model_passthrough_is_bit_identical_to_dense() {
        // dense_passthrough resolves every site to the same weight slabs,
        // so the model forward must match the dense forward exactly
        let (w, toks, b, s) = setup();
        let m = CompressedModel::dense_passthrough(w.clone());
        assert_eq!(nll(&w, &toks, b, s), nll_model(&m, &toks, b, s));
        let mut sd = CalibSums::new(&w.config);
        let mut sm = CalibSums::new(&w.config);
        accumulate_calib(&w, &toks, b, s, &mut sd);
        accumulate_calib_model(&m, &toks, b, s, &mut sm);
        assert_eq!(sd.tokens, sm.tokens);
        for slot in 0..4 {
            for l in 0..w.config.layers {
                assert_eq!(sd.grams[slot][l].data, sm.grams[slot][l].data);
                assert_eq!(sd.absmean[slot][l], sm.absmean[slot][l]);
            }
        }
    }
}
