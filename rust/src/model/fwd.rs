//! Pure-Rust reference forward pass (test oracle + serving backend).
//!
//! Functionally a port of `python/compile/model.py`, used to cross-check
//! the AOT artifacts and the runtime-built XLA graphs at tiny sizes, to
//! back the coordinator's artifact-free `RefBackend`, and — via the
//! [`CalibSums`] observer — to collect calibration statistics without the
//! PJRT `calib` artifact.
//!
//! Execution is batched, not scalar: every projection site resolves to a
//! [`Linear`] operator and runs as a row-band-parallel GEMM over all
//! `batch·t` activation rows at once (`tensor::matmul::gemm_f32` on the
//! `util::parallel` pool). The same forward therefore serves *dense*
//! weights ([`nll`]) and *factored* compressed models ([`nll_model`]) —
//! a factored site executes `(x·B)·C` directly and never rematerializes
//! the dense weight. Per-row floating-point order is independent of the
//! band split, so all outputs are bit-identical for any thread count
//! (enforced by `rust/tests/forward_equivalence.rs`).
//!
//! Generation runs through the same per-layer blocks on an explicit
//! [`DecodeState`] (per-layer roped K/V caches + position counter), with
//! two entry points sharing one code path:
//!  - [`prefill`] — the batched pass above, now also *writing* the cache
//!    for every prompt position (`Stage::Prefill`);
//!  - [`decode_step`] — a serial single-token pass that reads the cache
//!    through a cache-aware streaming-softmax variant and drives every
//!    projection through the packed row-vector kernel
//!    (`Linear::matvec` / `vecmat_f32_packed`, `Stage::Decode`).
//! Every per-row op in decode replays the batched path's per-row FP order
//! exactly (the kernels are row-local), so a decode step's logits are
//! **bitwise identical** to the batched forward's logits at the same
//! position — and trivially thread-invariant, since decode never spawns
//! (enforced by `rust/tests/decode.rs`).

use super::lowrank::{CompressedModel, Linear};
use super::{rope_tables, ModelConfig, RopeTables, Weights};
use crate::tensor::matmul::{gemm_f32_packed_serial, vecmat_f32_packed, PackedMat};
use crate::tensor::MatF;
use crate::util::parallel::parallel_row_bands;
use crate::util::profile::{self, Stage};
use crate::util::rng::Rng;
use std::sync::Arc;

const EPS: f32 = 1e-5;

// Streaming-softmax attention tiles: TQ query rows share each loaded
// key/value tile of TK rows. Sized so one (TQ·hd + 2·TK·hd) working set
// stays L1-resident at every config's head_dim.
const ATTN_TQ: usize = 16;
const ATTN_TK: usize = 32;

// Fused lm_head/cross-entropy chunk: rows of logits materialized at once
// per band thread (peak logits memory = threads · NLL_CHUNK · vocab, not
// batch·seq · vocab).
const NLL_CHUNK: usize = 16;

// Calibration slots (must mirror `calib::gram_slot`):
// 0 = input to wq/wk/wv, 1 = input to wo, 2 = input to w_gate/w_up,
// 3 = input to w_down.
const SLOT_ATTN: usize = 0;
const SLOT_O: usize = 1;
const SLOT_MLP: usize = 2;
const SLOT_DOWN: usize = 3;

/// Parameter source for one forward pass: plain dense weights or a
/// compressed model whose factored sites run on their factors. All the
/// block code below is written against this, so dense and factored
/// execution share every instruction except the [`Linear::matmul`]
/// dispatch.
#[derive(Clone, Copy)]
enum Params<'a> {
    Dense(&'a Weights),
    Model(&'a CompressedModel),
}

impl<'a> Params<'a> {
    fn weights(&self) -> &'a Weights {
        match self {
            Params::Dense(w) => w,
            Params::Model(m) => &m.base,
        }
    }

    /// The [`Linear`] operator serving (type, layer).
    fn linear(&self, typ: &str, l: usize) -> Linear<'a> {
        match self {
            Params::Dense(w) => {
                let (d1, d2) = w.config.matrix_dims(typ);
                let t = &w.tensors[ModelConfig::param_index(typ)];
                Linear::Dense {
                    w: &t.data[l * d1 * d2..(l + 1) * d1 * d2],
                    d1,
                    d2,
                    pack: Some(w.packs.site(typ, l)),
                }
            }
            Params::Model(m) => m.linear(typ, l),
        }
    }

    /// The lm_head's packed panels (packed once per model instance; the
    /// lm_head is never compressed, so both variants use the base registry).
    fn lm_packed(&self) -> &'a PackedMat {
        let w = self.weights();
        let lm = w.by_name("lm_head");
        let (d, v) = (w.config.d, w.config.vocab);
        w.packs.lm_head().get_or_init(|| PackedMat::pack(&lm.data, d, v))
    }
}

/// Raw calibration sums accumulated by the instrumented forward:
/// un-normalized Σ x·xᵀ per (slot, layer) and Σ|x| per (slot, layer, dim),
/// matching the wire semantics of the AOT `calib` artifact (the caller
/// normalizes by total tokens, exactly like `calib::run`).
pub struct CalibSums {
    pub grams: Vec<Vec<MatF>>,
    pub absmean: Vec<Vec<Vec<f64>>>,
    pub tokens: usize,
}

impl CalibSums {
    pub fn new(cfg: &ModelConfig) -> Self {
        let slot_dim = [cfg.d, cfg.d, cfg.d, cfg.dff];
        Self {
            grams: slot_dim
                .iter()
                .map(|&d| (0..cfg.layers).map(|_| MatF::zeros(d, d)).collect())
                .collect(),
            absmean: slot_dim.iter().map(|&d| vec![vec![0.0; d]; cfg.layers]).collect(),
            tokens: 0,
        }
    }

    /// Accumulate one projection-input vector into (slot, layer).
    fn record(&mut self, slot: usize, layer: usize, x: &[f32]) {
        let d = x.len();
        let g = &mut self.grams[slot][layer];
        debug_assert_eq!(g.rows, d);
        for i in 0..d {
            let xi = x[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let row = &mut g.data[i * d..(i + 1) * d];
            for (j, rj) in row.iter_mut().enumerate() {
                *rj += xi * x[j] as f64;
            }
        }
        let am = &mut self.absmean[slot][layer];
        for i in 0..d {
            am[i] += x[i].abs() as f64;
        }
    }

    /// Accumulate every row of a `rows`×`d` activation buffer, in row
    /// order (b-major, position-minor — the order the scalar forward
    /// recorded in, so sums stay bit-identical to the historical path).
    fn record_rows(&mut self, slot: usize, layer: usize, x: &[f32], d: usize) {
        for row in x.chunks_exact(d) {
            self.record(slot, layer, row);
        }
    }

    /// Fold another accumulator into this one (elementwise sums). The
    /// parallel calibration path computes one `CalibSums` per batch and
    /// merges them in batch order, so results don't depend on thread count.
    pub fn merge(&mut self, other: &CalibSums) {
        for slot in 0..self.grams.len() {
            for l in 0..self.grams[slot].len() {
                self.grams[slot][l].add_assign(&other.grams[slot][l]);
                for (a, b) in
                    self.absmean[slot][l].iter_mut().zip(&other.absmean[slot][l])
                {
                    *a += b;
                }
            }
        }
        self.tokens += other.tokens;
    }
}

/// Run the reference forward over one `[batch, seq]` token window while
/// accumulating calibration statistics into `sums` (the artifact-free twin
/// of streaming a batch through the AOT `calib` artifact).
pub fn accumulate_calib(
    w: &Weights,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    sums: &mut CalibSums,
) {
    // the AOT calib artifact embeds the full [B, S] window (no next-token
    // trim), so statistics cover all `seq` positions — mirror that exactly
    let _ = forward_hidden_obs(Params::Dense(w), tokens, batch, seq, seq, Some(sums), None);
    sums.tokens += batch * seq;
}

/// [`accumulate_calib`] over a compressed model: factored sites run on
/// their factors, so compensated recalibration observes the compressed
/// network without reconstructing dense weights.
pub fn accumulate_calib_model(
    m: &CompressedModel,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    sums: &mut CalibSums,
) {
    let _ = forward_hidden_obs(Params::Model(m), tokens, batch, seq, seq, Some(sums), None);
    sums.tokens += batch * seq;
}

/// Per-token NLL for a [batch, seq] token matrix; returns [batch, seq-1].
pub fn nll(w: &Weights, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
    nll_impl(Params::Dense(w), tokens, batch, seq)
}

/// [`nll`] over a compressed model, consuming factored weights directly —
/// the serving path for `RefBackend`'s factored mode, `eval::ppl_reference`,
/// and the factored-vs-dense equivalence suite.
pub fn nll_model(m: &CompressedModel, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
    nll_impl(Params::Model(m), tokens, batch, seq)
}

fn nll_impl(p: Params<'_>, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
    let cfg = p.weights().config;
    let t = seq - 1;
    let rows = batch * t;
    let hidden = forward_hidden_obs(p, tokens, batch, seq, t, None, None);
    // fused lm_head projection + cross entropy: each band thread projects
    // its rows in NLL_CHUNK-row chunks through the packed lm_head panels
    // into a small logits scratch and consumes it immediately, so the
    // rows×V logits slab is never materialized. Chunked serial GEMM keeps
    // every logit's FP order identical to the one-big-GEMM path (the packed
    // kernel's accumulation order per output element is row-local).
    let (d, v) = (cfg.d, cfg.vocab);
    let lmp = p.lm_packed();
    let mut out = vec![0.0f32; rows];
    profile::time(Stage::Fwd, || {
        parallel_row_bands(&mut out, rows, 1, |row0, band| {
            let mut logits = vec![0.0f32; NLL_CHUNK * v];
            let mut r0 = row0;
            for chunk in band.chunks_mut(NLL_CHUNK) {
                let bn = chunk.len();
                let lbuf = &mut logits[..bn * v];
                gemm_f32_packed_serial(&hidden[r0 * d..(r0 + bn) * d], bn, d, lmp, lbuf);
                for (i, o) in chunk.iter_mut().enumerate() {
                    let r = r0 + i;
                    let row = &lbuf[i * v..(i + 1) * v];
                    let max = row.iter().cloned().fold(f32::MIN, f32::max);
                    let logz = max + row.iter().map(|x| (x - max).exp()).sum::<f32>().ln();
                    let (b, pos) = (r / t, r % t);
                    let target = tokens[b * seq + pos + 1] as usize;
                    *o = logz - row[target];
                }
                r0 += bn;
            }
        });
    });
    out
}

/// Final normed hidden states for inputs tokens[:, :t]; [batch*t*d].
pub fn forward_hidden(
    w: &Weights,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    t: usize,
) -> Vec<f32> {
    forward_hidden_obs(Params::Dense(w), tokens, batch, seq, t, None, None)
}

/// Forward with an optional calibration observer hooked on the inputs of
/// every compressible projection, and an optional [`DecodeState`] cache
/// that prefill fills with every layer's roped K/V rows (cache implies
/// `batch == 1` — one state per sequence).
fn forward_hidden_obs(
    p: Params<'_>,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    t: usize,
    mut sums: Option<&mut CalibSums>,
    mut cache: Option<&mut DecodeState>,
) -> Vec<f32> {
    let cfg = p.weights().config;
    let d = cfg.d;
    let embed = p.weights().by_name("embed");
    let mut x = vec![0.0f32; batch * t * d];
    for b in 0..batch {
        for pos in 0..t {
            let tok = tokens[b * seq + pos] as usize;
            x[(b * t + pos) * d..(b * t + pos + 1) * d]
                .copy_from_slice(&embed.data[tok * d..(tok + 1) * d]);
        }
    }
    // prefill indexes the state's capacity-length rope table: entries are a
    // pure function of (position, lane), so it is bitwise identical to a
    // t-length table over positions < t
    let rope = match cache.as_deref() {
        Some(st) => st.rope.clone(),
        None => rope_tables(t, cfg.head_dim()),
    };
    for l in 0..cfg.layers {
        attention_block(
            p,
            &mut x,
            batch,
            t,
            l,
            &rope,
            sums.as_deref_mut(),
            cache.as_deref_mut(),
        );
        mlp_block(p, &mut x, batch, t, l, sums.as_deref_mut());
    }
    // final rmsnorm, row-parallel
    let fnorm = &p.weights().by_name("final_norm").data;
    parallel_row_bands(&mut x, batch * t, d, |_, band| {
        for row in band.chunks_exact_mut(d) {
            rmsnorm_inplace(row, fnorm);
        }
    });
    x
}

fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + EPS).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

fn rmsnorm_inplace(x: &mut [f32], w: &[f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + EPS).sqrt();
    for i in 0..x.len() {
        x[i] *= inv * w[i];
    }
}

/// Normalize every row of `x` into a fresh buffer, row-parallel.
fn rmsnorm_rows(x: &[f32], w: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    parallel_row_bands(&mut out, rows, d, |row0, band| {
        for (i, orow) in band.chunks_exact_mut(d).enumerate() {
            let r = row0 + i;
            rmsnorm(&x[r * d..(r + 1) * d], w, orow);
        }
    });
    out
}

/// y += o, elementwise over the residual stream, row-parallel.
fn residual_add(x: &mut [f32], o: &[f32], rows: usize, d: usize) {
    parallel_row_bands(x, rows, d, |row0, band| {
        let base = row0 * d;
        for (i, xv) in band.iter_mut().enumerate() {
            *xv += o[base + i];
        }
    });
}

/// rotate-half rope on one head vector at position p.
fn apply_rope(v: &mut [f32], p: usize, cos: &[f32], sin: &[f32]) {
    let half = v.len() / 2;
    for i in 0..half {
        let c = cos[p * half + i];
        let s = sin[p * half + i];
        let x1 = v[i];
        let x2 = v[half + i];
        v[i] = x1 * c - x2 * s;
        v[half + i] = x2 * c + x1 * s;
    }
}

/// Blocked streaming-softmax attention over roped q/k/v buffers.
///
/// Work units are (batch, head) pairs; the output is head-major,
/// `batch·h` rows of `t·hd` — one contiguous band per unit, so
/// `parallel_row_bands` hands each thread whole units. Within a unit,
/// query rows are processed in tiles of [`ATTN_TQ`] and keys/values in
/// tiles of [`ATTN_TK`] (flash-attention style): each query keeps a running
/// max `m`, denominator `l`, and unnormalized accumulator; when a tile
/// raises the max, the accumulator and denominator are rescaled by
/// `exp(m_old − m_new)` once, and the final division by `l` normalizes.
///
/// Determinism: for every output element the FP op sequence is a pure
/// function of (t, hd, the tile constants) — tiles run in ascending key
/// order and the thread split only chooses *which* units a thread runs,
/// never the op order inside one. Hence 1/2/4-thread outputs are
/// `to_bits`-identical (`rust/tests/determinism.rs`), and the kernel
/// matches the exact two-pass softmax to ~1e-7 (pinned at 1e-5 against the
/// scalar oracle in `rust/tests/forward_equivalence.rs`).
#[allow(clippy::too_many_arguments)]
fn attention_streaming(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    batch: usize,
    t: usize,
    kvd: usize,
    h: usize,
    rep: usize,
    hd: usize,
    scale: f32,
) -> Vec<f32> {
    let d = h * hd;
    let units = batch * h;
    let mut hm = vec![0.0f32; units * t * hd];
    parallel_row_bands(&mut hm, units, t * hd, |u0, band| {
        let mut scores = [0.0f32; ATTN_TK];
        let mut mrow = [f32::MIN; ATTN_TQ]; // running max per query
        let mut lrow = [0.0f32; ATTN_TQ]; // running denominator per query
        for (ui, ub) in band.chunks_exact_mut(t * hd).enumerate() {
            let u = u0 + ui;
            let (b, head) = (u / h, u % h);
            let kv_head = head / rep;
            for q0 in (0..t).step_by(ATTN_TQ) {
                let q1 = (q0 + ATTN_TQ).min(t);
                mrow[..q1 - q0].fill(f32::MIN);
                lrow[..q1 - q0].fill(0.0);
                // causal: keys 0..q1 suffice for every query in the tile
                for k0 in (0..q1).step_by(ATTN_TK) {
                    let k1 = (k0 + ATTN_TK).min(q1);
                    // queries before k0 see nothing of this tile
                    for qi in q0.max(k0)..q1 {
                        let kend = k1.min(qi + 1);
                        let qv = &q[(b * t + qi) * d + head * hd..][..hd];
                        let mut tmax = f32::MIN;
                        for j in k0..kend {
                            let kv = &k[(b * t + j) * kvd + kv_head * hd..][..hd];
                            let s =
                                qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                            scores[j - k0] = s;
                            tmax = tmax.max(s);
                        }
                        let mi = qi - q0;
                        let acc = &mut ub[qi * hd..(qi + 1) * hd];
                        if tmax > mrow[mi] {
                            // rescale history to the new max (first tile has
                            // no history: lrow is 0 and acc is all zeros)
                            if lrow[mi] > 0.0 {
                                let corr = (mrow[mi] - tmax).exp();
                                for a in acc.iter_mut() {
                                    *a *= corr;
                                }
                                lrow[mi] *= corr;
                            }
                            mrow[mi] = tmax;
                        }
                        for j in k0..kend {
                            let pj = (scores[j - k0] - mrow[mi]).exp();
                            lrow[mi] += pj;
                            let vv = &v[(b * t + j) * kvd + kv_head * hd..][..hd];
                            for (a, &vx) in acc.iter_mut().zip(vv) {
                                *a += pj * vx;
                            }
                        }
                    }
                }
                for qi in q0..q1 {
                    let inv = 1.0 / lrow[qi - q0];
                    for a in &mut ub[qi * hd..(qi + 1) * hd] {
                        *a *= inv;
                    }
                }
            }
        }
    });
    hm
}

#[allow(clippy::too_many_arguments)]
fn attention_block(
    p: Params<'_>,
    x: &mut [f32],
    batch: usize,
    t: usize,
    l: usize,
    rope: &RopeTables,
    mut sums: Option<&mut CalibSums>,
    cache: Option<&mut DecodeState>,
) {
    let w = p.weights();
    let cfg = w.config;
    let (d, h, kvh, hd) = (cfg.d, cfg.heads, cfg.kv_heads, cfg.head_dim());
    let kvd = cfg.kvd();
    let an = &w.by_name("attn_norm").data[l * d..(l + 1) * d];
    let rep = h / kvh;
    let scale = 1.0 / (hd as f32).sqrt();
    let rows = batch * t;

    // pre-projection norm over every row, then one GEMM per projection
    let xn = rmsnorm_rows(x, an, rows, d);
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_ATTN, l, &xn, d);
    }
    let mut q = p.linear("wq", l).matmul(&xn, rows);
    let mut k = p.linear("wk", l).matmul(&xn, rows);
    let v = p.linear("wv", l).matmul(&xn, rows);
    // rope, row-parallel (a row's position is r % t)
    parallel_row_bands(&mut q, rows, d, |row0, band| {
        for (i, row) in band.chunks_exact_mut(d).enumerate() {
            let pos = (row0 + i) % t;
            for head in 0..h {
                apply_rope(&mut row[head * hd..(head + 1) * hd], pos, &rope.cos, &rope.sin);
            }
        }
    });
    parallel_row_bands(&mut k, rows, kvd, |row0, band| {
        for (i, row) in band.chunks_exact_mut(kvd).enumerate() {
            let pos = (row0 + i) % t;
            for head in 0..kvh {
                apply_rope(&mut row[head * hd..(head + 1) * hd], pos, &rope.cos, &rope.sin);
            }
        }
    });
    // prefill: persist this layer's roped keys and (unroped) values so
    // decode can extend the sequence without recomputing the prefix
    if let Some(st) = cache {
        debug_assert_eq!(batch, 1, "a DecodeState caches exactly one sequence");
        st.k[l][..t * kvd].copy_from_slice(&k);
        st.v[l][..t * kvd].copy_from_slice(&v);
    }
    // blocked streaming-softmax attention (flash-style): head-major units
    // fan out across threads, each unit runs key/value tiles with a running
    // max/denominator; then a deterministic transpose back to row-major.
    // Profiled as its own `attn` stage (it is not a GEMM).
    let attn = profile::time(Stage::Attn, || {
        let hm = attention_streaming(&q, &k, &v, batch, t, kvd, h, rep, hd, scale);
        let mut attn = vec![0.0f32; rows * d];
        parallel_row_bands(&mut attn, rows, d, |row0, band| {
            for (i, row) in band.chunks_exact_mut(d).enumerate() {
                let r = row0 + i;
                let (b, pos) = (r / t, r % t);
                for head in 0..h {
                    let src = &hm[((b * h + head) * t + pos) * hd..][..hd];
                    row[head * hd..(head + 1) * hd].copy_from_slice(src);
                }
            }
        });
        attn
    });
    // output projection + residual
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_O, l, &attn, d);
    }
    let o = p.linear("wo", l).matmul(&attn, rows);
    residual_add(x, &o, rows, d);
}

fn mlp_block(
    p: Params<'_>,
    x: &mut [f32],
    batch: usize,
    t: usize,
    l: usize,
    mut sums: Option<&mut CalibSums>,
) {
    let w = p.weights();
    let cfg = w.config;
    let (d, dff) = (cfg.d, cfg.dff);
    let mn = &w.by_name("mlp_norm").data[l * d..(l + 1) * d];
    let rows = batch * t;

    let xn = rmsnorm_rows(x, mn, rows, d);
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_MLP, l, &xn, d);
    }
    let mut g = p.linear("w_gate", l).matmul(&xn, rows);
    let u = p.linear("w_up", l).matmul(&xn, rows);
    // silu(g) * u, elementwise row-parallel
    parallel_row_bands(&mut g, rows, dff, |row0, band| {
        let base = row0 * dff;
        for (i, gv) in band.iter_mut().enumerate() {
            let s = *gv / (1.0 + (-*gv).exp());
            *gv = s * u[base + i];
        }
    });
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_DOWN, l, &g, dff);
    }
    let o = p.linear("w_down", l).matmul(&g, rows);
    residual_add(x, &o, rows, d);
}

// ---------------------------------------------------------------------------
// KV-cached generation: prefill / decode_step
// ---------------------------------------------------------------------------

/// Incremental generation state for ONE sequence: per-layer roped key and
/// value caches plus the absolute position counter. [`prefill`] fills
/// positions `0..prompt_len` in one batched pass; [`decode_step`] appends
/// one position per call. Capacity is fixed at construction (prompt +
/// max new tokens), and the rope table is fetched once from the
/// process-global registry at that length — table entries depend only on
/// (position, lane), so indexing the capacity-length table by absolute
/// position is bitwise identical to any shorter table.
pub struct DecodeState {
    /// per-layer roped keys, each `capacity × kvd`, valid below `pos`
    k: Vec<Vec<f32>>,
    /// per-layer values, same layout
    v: Vec<Vec<f32>>,
    pos: usize,
    cap: usize,
    rope: Arc<RopeTables>,
}

impl DecodeState {
    /// Allocate caches for up to `capacity` total positions (prompt +
    /// generated) of a model shaped by `cfg`.
    pub fn new(cfg: &ModelConfig, capacity: usize) -> Self {
        let kvd = cfg.kvd();
        DecodeState {
            k: (0..cfg.layers).map(|_| vec![0.0f32; capacity * kvd]).collect(),
            v: (0..cfg.layers).map(|_| vec![0.0f32; capacity * kvd]).collect(),
            pos: 0,
            cap: capacity,
            rope: rope_tables(capacity, cfg.head_dim()),
        }
    }

    /// Positions filled so far (prompt + decoded tokens).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Total positions the caches can hold.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

/// Run the batched forward over a prompt, writing every layer's roped K/V
/// rows into `state`, and return the logits predicting the token after the
/// prompt (`[vocab]`). This IS the scoring forward — same blocks, same
/// kernels, same row-band parallelism (and therefore the same bit-identity
/// across thread counts) — plus the cache writes; timed under
/// `Stage::Prefill`.
pub fn prefill(w: &Weights, prompt: &[i32], state: &mut DecodeState) -> Vec<f32> {
    prefill_impl(Params::Dense(w), prompt, state)
}

/// [`prefill`] over a compressed model: factored sites run on their
/// factors, dense weights are never rematerialized.
pub fn prefill_model(m: &CompressedModel, prompt: &[i32], state: &mut DecodeState) -> Vec<f32> {
    prefill_impl(Params::Model(m), prompt, state)
}

fn prefill_impl(p: Params<'_>, prompt: &[i32], state: &mut DecodeState) -> Vec<f32> {
    let cfg = p.weights().config;
    let t = prompt.len();
    assert!(t >= 1, "prefill needs a non-empty prompt");
    assert_eq!(state.pos, 0, "prefill requires a fresh DecodeState");
    assert!(t <= state.cap, "prompt ({t}) exceeds DecodeState capacity ({})", state.cap);
    profile::time(Stage::Prefill, || {
        let hidden = forward_hidden_obs(p, prompt, 1, t, t, None, Some(state));
        state.pos = t;
        let d = cfg.d;
        let mut logits = vec![0.0f32; cfg.vocab];
        vecmat_f32_packed(&hidden[(t - 1) * d..t * d], p.lm_packed(), &mut logits);
        logits
    })
}

/// One cached decode step: feed the next `token`, append its roped K/V to
/// every layer's cache, and return the logits predicting the following
/// token (`[vocab]`); timed under `Stage::Decode`.
///
/// The entire step is serial — one token is far too little work to spawn
/// for — and every op replays the batched path's per-row FP order exactly
/// (projections via the packed vecmat kernel, attention via the same
/// key-tile schedule the streaming kernel uses for the last query row), so
/// the logits are **bitwise identical** to what a full batched forward
/// over the extended prefix would produce at this position, and trivially
/// `to_bits`-identical across thread counts.
pub fn decode_step(w: &Weights, token: i32, state: &mut DecodeState) -> Vec<f32> {
    decode_impl(Params::Dense(w), token, state)
}

/// [`decode_step`] over a compressed model (factored sites run `(x·B)·C`
/// as two packed vecmats through the shared scratch).
pub fn decode_step_model(m: &CompressedModel, token: i32, state: &mut DecodeState) -> Vec<f32> {
    decode_impl(Params::Model(m), token, state)
}

fn decode_impl(p: Params<'_>, token: i32, state: &mut DecodeState) -> Vec<f32> {
    let w = p.weights();
    let cfg = w.config;
    assert!(state.pos < state.cap, "DecodeState is full (capacity {})", state.cap);
    profile::time(Stage::Decode, || {
        let d = cfg.d;
        let tok = token as usize;
        let embed = w.by_name("embed");
        let mut x = embed.data[tok * d..(tok + 1) * d].to_vec();
        for l in 0..cfg.layers {
            attention_decode_block(p, &mut x, l, state);
            mlp_decode_block(p, &mut x, l);
        }
        rmsnorm_inplace(&mut x, &w.by_name("final_norm").data);
        let mut logits = vec![0.0f32; cfg.vocab];
        vecmat_f32_packed(&x, p.lm_packed(), &mut logits);
        state.pos += 1;
        logits
    })
}

/// Cache-aware variant of [`attention_streaming`] for a single query row at
/// position `t_keys - 1`: the same [`ATTN_TK`] key-tile schedule, running
/// max/denominator, and rescale-on-new-max — for the last row of a batched
/// pass the two kernels execute the identical FP op sequence, which is what
/// makes decode bitwise-equal to prefill. Serial by design (decode's
/// thread-invariance falls out of having no spawns at all).
#[allow(clippy::too_many_arguments)]
fn attention_decode(
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    t_keys: usize,
    kvd: usize,
    h: usize,
    rep: usize,
    hd: usize,
    scale: f32,
    out: &mut [f32],
) {
    let mut scores = [0.0f32; ATTN_TK];
    for head in 0..h {
        let kv_head = head / rep;
        let qv = &q[head * hd..(head + 1) * hd];
        let acc = &mut out[head * hd..(head + 1) * hd];
        acc.fill(0.0);
        let mut m = f32::MIN; // running max
        let mut lsum = 0.0f32; // running denominator
        for k0 in (0..t_keys).step_by(ATTN_TK) {
            let kend = (k0 + ATTN_TK).min(t_keys);
            let mut tmax = f32::MIN;
            for j in k0..kend {
                let kv = &kc[j * kvd + kv_head * hd..][..hd];
                let s = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                scores[j - k0] = s;
                tmax = tmax.max(s);
            }
            if tmax > m {
                if lsum > 0.0 {
                    let corr = (m - tmax).exp();
                    for a in acc.iter_mut() {
                        *a *= corr;
                    }
                    lsum *= corr;
                }
                m = tmax;
            }
            for j in k0..kend {
                let pj = (scores[j - k0] - m).exp();
                lsum += pj;
                let vv = &vc[j * kvd + kv_head * hd..][..hd];
                for (a, &vx) in acc.iter_mut().zip(vv) {
                    *a += pj * vx;
                }
            }
        }
        let inv = 1.0 / lsum;
        for a in acc.iter_mut() {
            *a *= inv;
        }
    }
}

/// Single-token twin of [`attention_block`]: rmsnorm → q/k/v vecmats →
/// rope at the absolute position → cache append → cached streaming
/// attention → output vecmat → residual.
fn attention_decode_block(p: Params<'_>, x: &mut [f32], l: usize, state: &mut DecodeState) {
    let w = p.weights();
    let cfg = w.config;
    let (d, h, kvh, hd) = (cfg.d, cfg.heads, cfg.kv_heads, cfg.head_dim());
    let kvd = cfg.kvd();
    let an = &w.by_name("attn_norm").data[l * d..(l + 1) * d];
    let rep = h / kvh;
    let scale = 1.0 / (hd as f32).sqrt();
    let pos = state.pos;

    let mut xn = vec![0.0f32; d];
    rmsnorm(x, an, &mut xn);
    let mut q = vec![0.0f32; d];
    let mut k = vec![0.0f32; kvd];
    let mut v = vec![0.0f32; kvd];
    p.linear("wq", l).matvec(&xn, &mut q);
    p.linear("wk", l).matvec(&xn, &mut k);
    p.linear("wv", l).matvec(&xn, &mut v);
    for head in 0..h {
        apply_rope(&mut q[head * hd..(head + 1) * hd], pos, &state.rope.cos, &state.rope.sin);
    }
    for head in 0..kvh {
        apply_rope(&mut k[head * hd..(head + 1) * hd], pos, &state.rope.cos, &state.rope.sin);
    }
    state.k[l][pos * kvd..(pos + 1) * kvd].copy_from_slice(&k);
    state.v[l][pos * kvd..(pos + 1) * kvd].copy_from_slice(&v);

    let mut attn = vec![0.0f32; d];
    profile::time(Stage::Attn, || {
        attention_decode(
            &q,
            &state.k[l],
            &state.v[l],
            pos + 1,
            kvd,
            h,
            rep,
            hd,
            scale,
            &mut attn,
        );
    });
    let mut o = vec![0.0f32; d];
    p.linear("wo", l).matvec(&attn, &mut o);
    for (xv, ov) in x.iter_mut().zip(&o) {
        *xv += ov;
    }
}

/// Single-token twin of [`mlp_block`].
fn mlp_decode_block(p: Params<'_>, x: &mut [f32], l: usize) {
    let w = p.weights();
    let cfg = w.config;
    let (d, dff) = (cfg.d, cfg.dff);
    let mn = &w.by_name("mlp_norm").data[l * d..(l + 1) * d];

    let mut xn = vec![0.0f32; d];
    rmsnorm(x, mn, &mut xn);
    let mut g = vec![0.0f32; dff];
    let mut u = vec![0.0f32; dff];
    p.linear("w_gate", l).matvec(&xn, &mut g);
    p.linear("w_up", l).matvec(&xn, &mut u);
    for (gv, &uv) in g.iter_mut().zip(&u) {
        let s = *gv / (1.0 + (-*gv).exp());
        *gv = s * uv;
    }
    let mut o = vec![0.0f32; d];
    p.linear("w_down", l).matvec(&g, &mut o);
    for (xv, ov) in x.iter_mut().zip(&o) {
        *xv += ov;
    }
}

// ---------------------------------------------------------------------------
// Sampling + the generation loop
// ---------------------------------------------------------------------------

/// Greedy argmax over logits; ties break toward the lowest token id, so
/// greedy decoding is fully deterministic.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Draw one token from softmax(logits / temperature) using the caller's
/// seeded [`Rng`] (`categorical` over f64 weights, max-subtracted for
/// stability) — deterministic for a given (seed, logits) stream.
pub fn sample_temperature(logits: &[f32], temperature: f64, rng: &mut Rng) -> i32 {
    assert!(temperature > 0.0, "temperature sampling needs temperature > 0");
    let max = logits.iter().cloned().fold(f32::MIN, f32::max) as f64;
    let weights: Vec<f64> =
        logits.iter().map(|&l| ((l as f64 - max) / temperature).exp()).collect();
    rng.categorical(&weights) as i32
}

/// Options for autoregressive generation. `temperature == 0.0` selects
/// greedy decoding; any positive temperature samples from the softmax with
/// a deterministic `util::rng` stream seeded by `seed`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GenerateOpts {
    pub max_new_tokens: usize,
    pub temperature: f64,
    pub seed: u64,
}

impl Default for GenerateOpts {
    fn default() -> Self {
        GenerateOpts { max_new_tokens: 16, temperature: 0.0, seed: 0 }
    }
}

/// Autoregressive generation: one [`prefill`] over the prompt, then one
/// [`decode_step`] per emitted token, sampling per [`GenerateOpts`].
/// Returns the generated token ids (never the prompt).
pub fn generate(w: &Weights, prompt: &[i32], opts: &GenerateOpts) -> Vec<i32> {
    generate_impl(Params::Dense(w), prompt, opts)
}

/// [`generate`] over a compressed model, on its factors.
pub fn generate_model(m: &CompressedModel, prompt: &[i32], opts: &GenerateOpts) -> Vec<i32> {
    generate_impl(Params::Model(m), prompt, opts)
}

fn generate_impl(p: Params<'_>, prompt: &[i32], opts: &GenerateOpts) -> Vec<i32> {
    let cfg = p.weights().config;
    if opts.max_new_tokens == 0 {
        return Vec::new();
    }
    let mut state = DecodeState::new(&cfg, prompt.len() + opts.max_new_tokens);
    let mut rng = Rng::new(opts.seed);
    let mut logits = prefill_impl(p, prompt, &mut state);
    let mut out = Vec::with_capacity(opts.max_new_tokens);
    loop {
        let tok = if opts.temperature > 0.0 {
            sample_temperature(&logits, opts.temperature, &mut rng)
        } else {
            argmax(&logits)
        };
        out.push(tok);
        if out.len() == opts.max_new_tokens {
            // the last token's logits would go unused — skip the step
            return out;
        }
        logits = decode_impl(p, tok, &mut state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lowrank::CompressedModel;
    use crate::model::{ModelConfig, Weights};
    use crate::util::rng::Rng;

    fn setup() -> (Weights, Vec<i32>, usize, usize) {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 3);
        let mut r = Rng::new(5);
        let (b, s) = (cfg.batch, cfg.seq);
        let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
        (w, toks, b, s)
    }

    #[test]
    fn nll_near_uniform_for_random_model() {
        let (w, toks, b, s) = setup();
        let out = nll(&w, &toks, b, s);
        assert_eq!(out.len(), b * (s - 1));
        let mean = out.iter().sum::<f32>() / out.len() as f32;
        let want = (w.config.vocab as f32).ln();
        assert!((mean - want).abs() < 1.0, "mean {mean} vs ln(V) {want}");
    }

    #[test]
    fn causality_future_tokens_do_not_matter() {
        let (w, mut toks, b, s) = setup();
        let a = nll(&w, &toks, b, s);
        // change the last token; all positions except the final prediction
        // target must be unaffected
        toks[s - 1] = (toks[s - 1] + 1).rem_euclid(w.config.vocab as i32);
        let c = nll(&w, &toks, b, s);
        let t = s - 1;
        for pos in 0..t - 1 {
            assert!((a[pos] - c[pos]).abs() < 1e-5, "pos {pos}");
        }
        assert!((a[t - 1] - c[t - 1]).abs() > 1e-7); // target changed
    }

    #[test]
    fn calib_sums_are_symmetric_and_positive() {
        let (w, toks, b, s) = setup();
        let mut sums = CalibSums::new(&w.config);
        accumulate_calib(&w, &toks, b, s, &mut sums);
        accumulate_calib(&w, &toks, b, s, &mut sums);
        assert_eq!(sums.tokens, 2 * b * s);
        for slot in 0..4 {
            let g = &sums.grams[slot][0];
            for i in 0..g.rows {
                assert!(g.at(i, i) >= 0.0);
                for j in 0..g.cols {
                    assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-6, "slot {slot} ({i},{j})");
                }
            }
        }
        assert_eq!(sums.grams[3][0].rows, w.config.dff);
        assert!(sums.absmean[0][0].iter().all(|&v| v >= 0.0));
        // the observer must not perturb the forward itself
        let plain = nll(&w, &toks, b, s);
        let again = nll(&w, &toks, b, s);
        assert_eq!(plain, again);
    }

    #[test]
    fn gqa_runs_and_is_finite() {
        let cfg = ModelConfig::by_name("gqa").unwrap();
        let w = Weights::init(cfg, 4);
        let mut r = Rng::new(6);
        let (b, s) = (1, 16);
        let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
        let out = nll(&w, &toks, b, s);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_logits_are_bitwise_equal_to_prefill_logits() {
        // the central numeric contract of the decode path: a decode step at
        // absolute position p produces the very same bits as a fresh
        // batched prefill over the (p+1)-token prefix
        let (w, toks, _b, _s) = setup();
        let (start, total) = (8usize, 13usize);
        let mut st = DecodeState::new(&w.config, total);
        let mut got = prefill(&w, &toks[..start], &mut st);
        assert_eq!(st.pos(), start);
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        for p in start..total {
            let mut fresh = DecodeState::new(&w.config, p);
            let want = prefill(&w, &toks[..p], &mut fresh);
            assert_eq!(bits(&got), bits(&want), "position {}", p - 1);
            got = decode_step(&w, toks[p], &mut st);
        }
        assert_eq!(st.pos(), total);
    }

    #[test]
    fn decode_matches_prefill_on_gqa() {
        let cfg = ModelConfig::by_name("gqa").unwrap();
        let w = Weights::init(cfg, 4);
        let mut r = Rng::new(6);
        let total = 9usize;
        let toks: Vec<i32> = (0..total).map(|_| r.below(cfg.vocab) as i32).collect();
        let mut st = DecodeState::new(&cfg, total);
        let mut got = prefill(&w, &toks[..4], &mut st);
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        for p in 4..total {
            let mut fresh = DecodeState::new(&cfg, p);
            let want = prefill(&w, &toks[..p], &mut fresh);
            assert_eq!(bits(&got), bits(&want), "gqa position {}", p - 1);
            got = decode_step(&w, toks[p], &mut st);
        }
    }

    #[test]
    fn generate_is_deterministic_and_greedy_matches_manual_loop() {
        let (w, toks, _b, _s) = setup();
        let prompt = &toks[..6];
        let opts = GenerateOpts { max_new_tokens: 5, temperature: 0.0, seed: 0 };
        let out = generate(&w, prompt, &opts);
        assert_eq!(out.len(), 5);
        assert_eq!(out, generate(&w, prompt, &opts), "greedy must be deterministic");
        // manual prefill + decode loop reproduces the same tokens
        let mut st = DecodeState::new(&w.config, prompt.len() + 5);
        let mut logits = prefill(&w, prompt, &mut st);
        let mut manual = Vec::new();
        for _ in 0..5 {
            let tok = argmax(&logits);
            manual.push(tok);
            if manual.len() < 5 {
                logits = decode_step(&w, tok, &mut st);
            }
        }
        assert_eq!(out, manual);
        // all ids must be valid vocab entries
        assert!(out.iter().all(|&t| (t as usize) < w.config.vocab));
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let (w, toks, _b, _s) = setup();
        let prompt = &toks[..6];
        let hot = GenerateOpts { max_new_tokens: 8, temperature: 1.0, seed: 42 };
        let a = generate(&w, prompt, &hot);
        let b = generate(&w, prompt, &hot);
        assert_eq!(a, b, "same seed must reproduce the same tokens");
        assert!(a.iter().all(|&t| (t as usize) < w.config.vocab));
        let other = GenerateOpts { seed: 43, ..hot };
        let c = generate(&w, prompt, &other);
        // different seeds will almost surely diverge somewhere in 8 draws
        // from a near-uniform distribution; equal streams would indicate
        // the seed is ignored
        assert_ne!(a, c, "different seeds should diverge");
    }

    #[test]
    fn generate_model_passthrough_matches_dense_generate() {
        let (w, toks, _b, _s) = setup();
        let m = CompressedModel::dense_passthrough(w.clone());
        let prompt = &toks[..6];
        let opts = GenerateOpts { max_new_tokens: 6, temperature: 0.0, seed: 0 };
        assert_eq!(generate(&w, prompt, &opts), generate_model(&m, prompt, &opts));
    }

    #[test]
    fn model_passthrough_is_bit_identical_to_dense() {
        // dense_passthrough resolves every site to the same weight slabs,
        // so the model forward must match the dense forward exactly
        let (w, toks, b, s) = setup();
        let m = CompressedModel::dense_passthrough(w.clone());
        assert_eq!(nll(&w, &toks, b, s), nll_model(&m, &toks, b, s));
        let mut sd = CalibSums::new(&w.config);
        let mut sm = CalibSums::new(&w.config);
        accumulate_calib(&w, &toks, b, s, &mut sd);
        accumulate_calib_model(&m, &toks, b, s, &mut sm);
        assert_eq!(sd.tokens, sm.tokens);
        for slot in 0..4 {
            for l in 0..w.config.layers {
                assert_eq!(sd.grams[slot][l].data, sm.grams[slot][l].data);
                assert_eq!(sd.absmean[slot][l], sm.absmean[slot][l]);
            }
        }
    }
}
