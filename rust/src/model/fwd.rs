//! Pure-Rust reference forward pass (test oracle + reference backend).
//!
//! A direct, loop-level port of `python/compile/model.py` used to
//! cross-check the AOT artifacts and the runtime-built XLA graphs at tiny
//! sizes, to back the coordinator's artifact-free `RefBackend`, and — via
//! the [`CalibSums`] observer — to collect calibration statistics without
//! the PJRT `calib` artifact. Single-threaded f32; not a performance path.

use super::{ModelConfig, Weights};
use crate::tensor::MatF;

const EPS: f32 = 1e-5;
const ROPE_THETA: f32 = 1e4;

// Calibration slots (must mirror `calib::gram_slot`):
// 0 = input to wq/wk/wv, 1 = input to wo, 2 = input to w_gate/w_up,
// 3 = input to w_down.
const SLOT_ATTN: usize = 0;
const SLOT_O: usize = 1;
const SLOT_MLP: usize = 2;
const SLOT_DOWN: usize = 3;

/// Raw calibration sums accumulated by the instrumented forward:
/// un-normalized Σ x·xᵀ per (slot, layer) and Σ|x| per (slot, layer, dim),
/// matching the wire semantics of the AOT `calib` artifact (the caller
/// normalizes by total tokens, exactly like `calib::run`).
pub struct CalibSums {
    pub grams: Vec<Vec<MatF>>,
    pub absmean: Vec<Vec<Vec<f64>>>,
    pub tokens: usize,
}

impl CalibSums {
    pub fn new(cfg: &ModelConfig) -> Self {
        let slot_dim = [cfg.d, cfg.d, cfg.d, cfg.dff];
        Self {
            grams: slot_dim
                .iter()
                .map(|&d| (0..cfg.layers).map(|_| MatF::zeros(d, d)).collect())
                .collect(),
            absmean: slot_dim.iter().map(|&d| vec![vec![0.0; d]; cfg.layers]).collect(),
            tokens: 0,
        }
    }

    /// Accumulate one projection-input vector into (slot, layer).
    fn record(&mut self, slot: usize, layer: usize, x: &[f32]) {
        let d = x.len();
        let g = &mut self.grams[slot][layer];
        debug_assert_eq!(g.rows, d);
        for i in 0..d {
            let xi = x[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let row = &mut g.data[i * d..(i + 1) * d];
            for (j, rj) in row.iter_mut().enumerate() {
                *rj += xi * x[j] as f64;
            }
        }
        let am = &mut self.absmean[slot][layer];
        for i in 0..d {
            am[i] += x[i].abs() as f64;
        }
    }

    /// Fold another accumulator into this one (elementwise sums). The
    /// parallel calibration path computes one `CalibSums` per batch and
    /// merges them in batch order, so results don't depend on thread count.
    pub fn merge(&mut self, other: &CalibSums) {
        for slot in 0..self.grams.len() {
            for l in 0..self.grams[slot].len() {
                self.grams[slot][l].add_assign(&other.grams[slot][l]);
                for (a, b) in
                    self.absmean[slot][l].iter_mut().zip(&other.absmean[slot][l])
                {
                    *a += b;
                }
            }
        }
        self.tokens += other.tokens;
    }
}

/// Run the reference forward over one `[batch, seq]` token window while
/// accumulating calibration statistics into `sums` (the artifact-free twin
/// of streaming a batch through the AOT `calib` artifact).
pub fn accumulate_calib(
    w: &Weights,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    sums: &mut CalibSums,
) {
    // the AOT calib artifact embeds the full [B, S] window (no next-token
    // trim), so statistics cover all `seq` positions — mirror that exactly
    let _ = forward_hidden_obs(w, tokens, batch, seq, seq, Some(sums));
    sums.tokens += batch * seq;
}

/// Per-token NLL for a [batch, seq] token matrix; returns [batch, seq-1].
pub fn nll(w: &Weights, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
    let cfg = w.config;
    let t = seq - 1;
    let hidden = forward_hidden(w, tokens, batch, seq, t);
    // logits + per-position cross entropy
    let lm = w.by_name("lm_head");
    let (d, v) = (cfg.d, cfg.vocab);
    let mut out = vec![0.0f32; batch * t];
    let mut logits = vec![0.0f32; v];
    for b in 0..batch {
        for pos in 0..t {
            let h = &hidden[(b * t + pos) * d..(b * t + pos + 1) * d];
            for x in logits.iter_mut() {
                *x = 0.0;
            }
            for (i, &hv) in h.iter().enumerate() {
                if hv == 0.0 {
                    continue;
                }
                let row = &lm.data[i * v..(i + 1) * v];
                for j in 0..v {
                    logits[j] += hv * row[j];
                }
            }
            let max = logits.iter().cloned().fold(f32::MIN, f32::max);
            let logz = max + logits.iter().map(|x| (x - max).exp()).sum::<f32>().ln();
            let target = tokens[b * seq + pos + 1] as usize;
            out[b * t + pos] = logz - logits[target];
        }
    }
    out
}

/// Final normed hidden states for inputs tokens[:, :t]; [batch*t*d].
pub fn forward_hidden(
    w: &Weights,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    t: usize,
) -> Vec<f32> {
    forward_hidden_obs(w, tokens, batch, seq, t, None)
}

/// Forward with an optional calibration observer hooked on the inputs of
/// every compressible projection.
fn forward_hidden_obs(
    w: &Weights,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    t: usize,
    mut sums: Option<&mut CalibSums>,
) -> Vec<f32> {
    let cfg = w.config;
    let d = cfg.d;
    let embed = w.by_name("embed");
    let mut x = vec![0.0f32; batch * t * d];
    for b in 0..batch {
        for pos in 0..t {
            let tok = tokens[b * seq + pos] as usize;
            x[(b * t + pos) * d..(b * t + pos + 1) * d]
                .copy_from_slice(&embed.data[tok * d..(tok + 1) * d]);
        }
    }
    let (cos, sin) = rope_tables(t, cfg.head_dim());
    for l in 0..cfg.layers {
        attention_block(w, &mut x, batch, t, l, &cos, &sin, sums.as_deref_mut());
        mlp_block(w, &mut x, batch, t, l, sums.as_deref_mut());
    }
    // final rmsnorm
    let fnorm = &w.by_name("final_norm").data;
    for row in x.chunks_exact_mut(d) {
        rmsnorm_inplace(row, fnorm);
    }
    x
}

fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + EPS).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

fn rmsnorm_inplace(x: &mut [f32], w: &[f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + EPS).sqrt();
    for i in 0..x.len() {
        x[i] *= inv * w[i];
    }
}

fn rope_tables(t: usize, hd: usize) -> (Vec<f32>, Vec<f32>) {
    let half = hd / 2;
    let mut cos = vec![0.0f32; t * half];
    let mut sin = vec![0.0f32; t * half];
    for p in 0..t {
        for i in 0..half {
            let freq = ROPE_THETA.powf(-(i as f32) / half as f32);
            let ang = p as f32 * freq;
            cos[p * half + i] = ang.cos();
            sin[p * half + i] = ang.sin();
        }
    }
    (cos, sin)
}

/// rotate-half rope on one head vector at position p.
fn apply_rope(v: &mut [f32], p: usize, cos: &[f32], sin: &[f32]) {
    let half = v.len() / 2;
    for i in 0..half {
        let c = cos[p * half + i];
        let s = sin[p * half + i];
        let x1 = v[i];
        let x2 = v[half + i];
        v[i] = x1 * c - x2 * s;
        v[half + i] = x2 * c + x1 * s;
    }
}

/// y[j] += x · W[:, j] for row-major W (d_in × d_out).
fn matvec_add(x: &[f32], w: &[f32], d_out: usize, y: &mut [f32]) {
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = &w[i * d_out..(i + 1) * d_out];
        for j in 0..d_out {
            y[j] += xv * row[j];
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn attention_block(
    w: &Weights,
    x: &mut [f32],
    batch: usize,
    t: usize,
    l: usize,
    cos: &[f32],
    sin: &[f32],
    mut sums: Option<&mut CalibSums>,
) {
    let cfg = w.config;
    let (d, h, kvh, hd) = (cfg.d, cfg.heads, cfg.kv_heads, cfg.head_dim());
    let kvd = cfg.kvd();
    let an = &w.by_name("attn_norm").data[l * d..(l + 1) * d];
    let wq = &w.by_name("wq").data[l * d * d..(l + 1) * d * d];
    let wk = &w.by_name("wk").data[l * d * kvd..(l + 1) * d * kvd];
    let wv = &w.by_name("wv").data[l * d * kvd..(l + 1) * d * kvd];
    let wo = &w.by_name("wo").data[l * d * d..(l + 1) * d * d];
    let rep = h / kvh;
    let scale = 1.0 / (hd as f32).sqrt();

    let mut xn = vec![0.0f32; d];
    for b in 0..batch {
        // project the whole sequence first
        let mut q = vec![0.0f32; t * d];
        let mut k = vec![0.0f32; t * kvd];
        let mut v = vec![0.0f32; t * kvd];
        for pos in 0..t {
            let row = &x[(b * t + pos) * d..(b * t + pos + 1) * d];
            rmsnorm(row, an, &mut xn);
            if let Some(s) = sums.as_deref_mut() {
                s.record(SLOT_ATTN, l, &xn);
            }
            matvec_add(&xn, wq, d, &mut q[pos * d..(pos + 1) * d]);
            matvec_add(&xn, wk, kvd, &mut k[pos * kvd..(pos + 1) * kvd]);
            matvec_add(&xn, wv, kvd, &mut v[pos * kvd..(pos + 1) * kvd]);
            for head in 0..h {
                apply_rope(&mut q[pos * d + head * hd..pos * d + (head + 1) * hd], pos, cos, sin);
            }
            for head in 0..kvh {
                apply_rope(
                    &mut k[pos * kvd + head * hd..pos * kvd + (head + 1) * hd],
                    pos,
                    cos,
                    sin,
                );
            }
        }
        // causal attention, head by head
        let mut attn = vec![0.0f32; t * d];
        let mut scores = vec![0.0f32; t];
        for head in 0..h {
            let kv_head = head / rep;
            for pos in 0..t {
                let qv = &q[pos * d + head * hd..pos * d + (head + 1) * hd];
                let mut max = f32::MIN;
                for j in 0..=pos {
                    let kv = &k[j * kvd + kv_head * hd..j * kvd + (kv_head + 1) * hd];
                    let s: f32 = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                    scores[j] = s;
                    max = max.max(s);
                }
                let mut denom = 0.0f32;
                for s in scores[..=pos].iter_mut() {
                    *s = (*s - max).exp();
                    denom += *s;
                }
                let out = &mut attn[pos * d + head * hd..pos * d + (head + 1) * hd];
                for j in 0..=pos {
                    let p = scores[j] / denom;
                    let vv = &v[j * kvd + kv_head * hd..j * kvd + (kv_head + 1) * hd];
                    for i in 0..hd {
                        out[i] += p * vv[i];
                    }
                }
            }
        }
        // output projection + residual
        for pos in 0..t {
            let row = &mut x[(b * t + pos) * d..(b * t + pos + 1) * d];
            if let Some(s) = sums.as_deref_mut() {
                s.record(SLOT_O, l, &attn[pos * d..(pos + 1) * d]);
            }
            let mut o = vec![0.0f32; d];
            matvec_add(&attn[pos * d..(pos + 1) * d], wo, d, &mut o);
            for i in 0..d {
                row[i] += o[i];
            }
        }
    }
}

fn mlp_block(
    w: &Weights,
    x: &mut [f32],
    batch: usize,
    t: usize,
    l: usize,
    mut sums: Option<&mut CalibSums>,
) {
    let cfg = w.config;
    let (d, dff) = (cfg.d, cfg.dff);
    let mn = &w.by_name("mlp_norm").data[l * d..(l + 1) * d];
    let wg = &w.by_name("w_gate").data[l * d * dff..(l + 1) * d * dff];
    let wu = &w.by_name("w_up").data[l * d * dff..(l + 1) * d * dff];
    let wd = &w.by_name("w_down").data[l * dff * d..(l + 1) * dff * d];
    let mut xn = vec![0.0f32; d];
    let mut g = vec![0.0f32; dff];
    let mut u = vec![0.0f32; dff];
    for bt in 0..batch * t {
        let row = &mut x[bt * d..(bt + 1) * d];
        rmsnorm(row, mn, &mut xn);
        if let Some(s) = sums.as_deref_mut() {
            s.record(SLOT_MLP, l, &xn);
        }
        g.iter_mut().for_each(|x| *x = 0.0);
        u.iter_mut().for_each(|x| *x = 0.0);
        matvec_add(&xn, wg, dff, &mut g);
        matvec_add(&xn, wu, dff, &mut u);
        for i in 0..dff {
            // silu(g) * u
            let s = g[i] / (1.0 + (-g[i]).exp());
            g[i] = s * u[i];
        }
        if let Some(s) = sums.as_deref_mut() {
            s.record(SLOT_DOWN, l, &g);
        }
        let mut o = vec![0.0f32; d];
        matvec_add(&g, wd, d, &mut o);
        for i in 0..d {
            row[i] += o[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Weights};
    use crate::util::rng::Rng;

    fn setup() -> (Weights, Vec<i32>, usize, usize) {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 3);
        let mut r = Rng::new(5);
        let (b, s) = (cfg.batch, cfg.seq);
        let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
        (w, toks, b, s)
    }

    #[test]
    fn nll_near_uniform_for_random_model() {
        let (w, toks, b, s) = setup();
        let out = nll(&w, &toks, b, s);
        assert_eq!(out.len(), b * (s - 1));
        let mean = out.iter().sum::<f32>() / out.len() as f32;
        let want = (w.config.vocab as f32).ln();
        assert!((mean - want).abs() < 1.0, "mean {mean} vs ln(V) {want}");
    }

    #[test]
    fn causality_future_tokens_do_not_matter() {
        let (w, mut toks, b, s) = setup();
        let a = nll(&w, &toks, b, s);
        // change the last token; all positions except the final prediction
        // target must be unaffected
        toks[s - 1] = (toks[s - 1] + 1).rem_euclid(w.config.vocab as i32);
        let c = nll(&w, &toks, b, s);
        let t = s - 1;
        for pos in 0..t - 1 {
            assert!((a[pos] - c[pos]).abs() < 1e-5, "pos {pos}");
        }
        assert!((a[t - 1] - c[t - 1]).abs() > 1e-7); // target changed
    }

    #[test]
    fn calib_sums_are_symmetric_and_positive() {
        let (w, toks, b, s) = setup();
        let mut sums = CalibSums::new(&w.config);
        accumulate_calib(&w, &toks, b, s, &mut sums);
        accumulate_calib(&w, &toks, b, s, &mut sums);
        assert_eq!(sums.tokens, 2 * b * s);
        for slot in 0..4 {
            let g = &sums.grams[slot][0];
            for i in 0..g.rows {
                assert!(g.at(i, i) >= 0.0);
                for j in 0..g.cols {
                    assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-6, "slot {slot} ({i},{j})");
                }
            }
        }
        assert_eq!(sums.grams[3][0].rows, w.config.dff);
        assert!(sums.absmean[0][0].iter().all(|&v| v >= 0.0));
        // the observer must not perturb the forward itself
        let plain = nll(&w, &toks, b, s);
        let again = nll(&w, &toks, b, s);
        assert_eq!(plain, again);
    }

    #[test]
    fn gqa_runs_and_is_finite() {
        let cfg = ModelConfig::by_name("gqa").unwrap();
        let w = Weights::init(cfg, 4);
        let mut r = Rng::new(6);
        let (b, s) = (1, 16);
        let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
        let out = nll(&w, &toks, b, s);
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
