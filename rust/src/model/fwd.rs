//! Pure-Rust reference forward pass (test oracle + serving backend).
//!
//! Functionally a port of `python/compile/model.py`, used to cross-check
//! the AOT artifacts and the runtime-built XLA graphs at tiny sizes, to
//! back the coordinator's artifact-free `RefBackend`, and — via the
//! [`CalibSums`] observer — to collect calibration statistics without the
//! PJRT `calib` artifact.
//!
//! Execution is batched, not scalar: every projection site resolves to a
//! [`Linear`] operator and runs as a row-band-parallel GEMM over all
//! `batch·t` activation rows at once (`tensor::matmul::gemm_f32` on the
//! `util::parallel` pool). The same forward therefore serves *dense*
//! weights ([`nll`]) and *factored* compressed models ([`nll_model`]) —
//! a factored site executes `(x·B)·C` directly and never rematerializes
//! the dense weight. Per-row floating-point order is independent of the
//! band split, so all outputs are bit-identical for any thread count
//! (enforced by `rust/tests/forward_equivalence.rs`).

use super::lowrank::{CompressedModel, Linear};
use super::{ModelConfig, Weights};
use crate::tensor::MatF;
use crate::util::parallel::parallel_row_bands;

const EPS: f32 = 1e-5;
const ROPE_THETA: f32 = 1e4;

// Calibration slots (must mirror `calib::gram_slot`):
// 0 = input to wq/wk/wv, 1 = input to wo, 2 = input to w_gate/w_up,
// 3 = input to w_down.
const SLOT_ATTN: usize = 0;
const SLOT_O: usize = 1;
const SLOT_MLP: usize = 2;
const SLOT_DOWN: usize = 3;

/// Parameter source for one forward pass: plain dense weights or a
/// compressed model whose factored sites run on their factors. All the
/// block code below is written against this, so dense and factored
/// execution share every instruction except the [`Linear::matmul`]
/// dispatch.
#[derive(Clone, Copy)]
enum Params<'a> {
    Dense(&'a Weights),
    Model(&'a CompressedModel),
}

impl<'a> Params<'a> {
    fn weights(&self) -> &'a Weights {
        match self {
            Params::Dense(w) => w,
            Params::Model(m) => &m.base,
        }
    }

    /// The [`Linear`] operator serving (type, layer).
    fn linear(&self, typ: &str, l: usize) -> Linear<'a> {
        match self {
            Params::Dense(w) => {
                let (d1, d2) = w.config.matrix_dims(typ);
                let t = &w.tensors[ModelConfig::param_index(typ)];
                Linear::Dense { w: &t.data[l * d1 * d2..(l + 1) * d1 * d2], d1, d2 }
            }
            Params::Model(m) => m.linear(typ, l),
        }
    }
}

/// Raw calibration sums accumulated by the instrumented forward:
/// un-normalized Σ x·xᵀ per (slot, layer) and Σ|x| per (slot, layer, dim),
/// matching the wire semantics of the AOT `calib` artifact (the caller
/// normalizes by total tokens, exactly like `calib::run`).
pub struct CalibSums {
    pub grams: Vec<Vec<MatF>>,
    pub absmean: Vec<Vec<Vec<f64>>>,
    pub tokens: usize,
}

impl CalibSums {
    pub fn new(cfg: &ModelConfig) -> Self {
        let slot_dim = [cfg.d, cfg.d, cfg.d, cfg.dff];
        Self {
            grams: slot_dim
                .iter()
                .map(|&d| (0..cfg.layers).map(|_| MatF::zeros(d, d)).collect())
                .collect(),
            absmean: slot_dim.iter().map(|&d| vec![vec![0.0; d]; cfg.layers]).collect(),
            tokens: 0,
        }
    }

    /// Accumulate one projection-input vector into (slot, layer).
    fn record(&mut self, slot: usize, layer: usize, x: &[f32]) {
        let d = x.len();
        let g = &mut self.grams[slot][layer];
        debug_assert_eq!(g.rows, d);
        for i in 0..d {
            let xi = x[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let row = &mut g.data[i * d..(i + 1) * d];
            for (j, rj) in row.iter_mut().enumerate() {
                *rj += xi * x[j] as f64;
            }
        }
        let am = &mut self.absmean[slot][layer];
        for i in 0..d {
            am[i] += x[i].abs() as f64;
        }
    }

    /// Accumulate every row of a `rows`×`d` activation buffer, in row
    /// order (b-major, position-minor — the order the scalar forward
    /// recorded in, so sums stay bit-identical to the historical path).
    fn record_rows(&mut self, slot: usize, layer: usize, x: &[f32], d: usize) {
        for row in x.chunks_exact(d) {
            self.record(slot, layer, row);
        }
    }

    /// Fold another accumulator into this one (elementwise sums). The
    /// parallel calibration path computes one `CalibSums` per batch and
    /// merges them in batch order, so results don't depend on thread count.
    pub fn merge(&mut self, other: &CalibSums) {
        for slot in 0..self.grams.len() {
            for l in 0..self.grams[slot].len() {
                self.grams[slot][l].add_assign(&other.grams[slot][l]);
                for (a, b) in
                    self.absmean[slot][l].iter_mut().zip(&other.absmean[slot][l])
                {
                    *a += b;
                }
            }
        }
        self.tokens += other.tokens;
    }
}

/// Run the reference forward over one `[batch, seq]` token window while
/// accumulating calibration statistics into `sums` (the artifact-free twin
/// of streaming a batch through the AOT `calib` artifact).
pub fn accumulate_calib(
    w: &Weights,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    sums: &mut CalibSums,
) {
    // the AOT calib artifact embeds the full [B, S] window (no next-token
    // trim), so statistics cover all `seq` positions — mirror that exactly
    let _ = forward_hidden_obs(Params::Dense(w), tokens, batch, seq, seq, Some(sums));
    sums.tokens += batch * seq;
}

/// [`accumulate_calib`] over a compressed model: factored sites run on
/// their factors, so compensated recalibration observes the compressed
/// network without reconstructing dense weights.
pub fn accumulate_calib_model(
    m: &CompressedModel,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    sums: &mut CalibSums,
) {
    let _ = forward_hidden_obs(Params::Model(m), tokens, batch, seq, seq, Some(sums));
    sums.tokens += batch * seq;
}

/// Per-token NLL for a [batch, seq] token matrix; returns [batch, seq-1].
pub fn nll(w: &Weights, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
    nll_impl(Params::Dense(w), tokens, batch, seq)
}

/// [`nll`] over a compressed model, consuming factored weights directly —
/// the serving path for `RefBackend`'s factored mode, `eval::ppl_reference`,
/// and the factored-vs-dense equivalence suite.
pub fn nll_model(m: &CompressedModel, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
    nll_impl(Params::Model(m), tokens, batch, seq)
}

fn nll_impl(p: Params<'_>, tokens: &[i32], batch: usize, seq: usize) -> Vec<f32> {
    let cfg = p.weights().config;
    let t = seq - 1;
    let rows = batch * t;
    let hidden = forward_hidden_obs(p, tokens, batch, seq, t, None);
    // batched logits: one rows×d×V GEMM (lm_head is never compressed)
    let lm = p.weights().by_name("lm_head");
    let (d, v) = (cfg.d, cfg.vocab);
    let logits = Linear::Dense { w: &lm.data, d1: d, d2: v }.matmul(&hidden, rows);
    // per-position cross entropy, row-parallel
    let mut out = vec![0.0f32; rows];
    parallel_row_bands(&mut out, rows, 1, |row0, band| {
        for (i, o) in band.iter_mut().enumerate() {
            let r = row0 + i;
            let row = &logits[r * v..(r + 1) * v];
            let max = row.iter().cloned().fold(f32::MIN, f32::max);
            let logz = max + row.iter().map(|x| (x - max).exp()).sum::<f32>().ln();
            let (b, pos) = (r / t, r % t);
            let target = tokens[b * seq + pos + 1] as usize;
            *o = logz - row[target];
        }
    });
    out
}

/// Final normed hidden states for inputs tokens[:, :t]; [batch*t*d].
pub fn forward_hidden(
    w: &Weights,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    t: usize,
) -> Vec<f32> {
    forward_hidden_obs(Params::Dense(w), tokens, batch, seq, t, None)
}

/// Forward with an optional calibration observer hooked on the inputs of
/// every compressible projection.
fn forward_hidden_obs(
    p: Params<'_>,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    t: usize,
    mut sums: Option<&mut CalibSums>,
) -> Vec<f32> {
    let cfg = p.weights().config;
    let d = cfg.d;
    let embed = p.weights().by_name("embed");
    let mut x = vec![0.0f32; batch * t * d];
    for b in 0..batch {
        for pos in 0..t {
            let tok = tokens[b * seq + pos] as usize;
            x[(b * t + pos) * d..(b * t + pos + 1) * d]
                .copy_from_slice(&embed.data[tok * d..(tok + 1) * d]);
        }
    }
    let (cos, sin) = rope_tables(t, cfg.head_dim());
    for l in 0..cfg.layers {
        attention_block(p, &mut x, batch, t, l, &cos, &sin, sums.as_deref_mut());
        mlp_block(p, &mut x, batch, t, l, sums.as_deref_mut());
    }
    // final rmsnorm, row-parallel
    let fnorm = &p.weights().by_name("final_norm").data;
    parallel_row_bands(&mut x, batch * t, d, |_, band| {
        for row in band.chunks_exact_mut(d) {
            rmsnorm_inplace(row, fnorm);
        }
    });
    x
}

fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + EPS).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

fn rmsnorm_inplace(x: &mut [f32], w: &[f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + EPS).sqrt();
    for i in 0..x.len() {
        x[i] *= inv * w[i];
    }
}

/// Normalize every row of `x` into a fresh buffer, row-parallel.
fn rmsnorm_rows(x: &[f32], w: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * d];
    parallel_row_bands(&mut out, rows, d, |row0, band| {
        for (i, orow) in band.chunks_exact_mut(d).enumerate() {
            let r = row0 + i;
            rmsnorm(&x[r * d..(r + 1) * d], w, orow);
        }
    });
    out
}

/// y += o, elementwise over the residual stream, row-parallel.
fn residual_add(x: &mut [f32], o: &[f32], rows: usize, d: usize) {
    parallel_row_bands(x, rows, d, |row0, band| {
        let base = row0 * d;
        for (i, xv) in band.iter_mut().enumerate() {
            *xv += o[base + i];
        }
    });
}

fn rope_tables(t: usize, hd: usize) -> (Vec<f32>, Vec<f32>) {
    let half = hd / 2;
    let mut cos = vec![0.0f32; t * half];
    let mut sin = vec![0.0f32; t * half];
    for p in 0..t {
        for i in 0..half {
            let freq = ROPE_THETA.powf(-(i as f32) / half as f32);
            let ang = p as f32 * freq;
            cos[p * half + i] = ang.cos();
            sin[p * half + i] = ang.sin();
        }
    }
    (cos, sin)
}

/// rotate-half rope on one head vector at position p.
fn apply_rope(v: &mut [f32], p: usize, cos: &[f32], sin: &[f32]) {
    let half = v.len() / 2;
    for i in 0..half {
        let c = cos[p * half + i];
        let s = sin[p * half + i];
        let x1 = v[i];
        let x2 = v[half + i];
        v[i] = x1 * c - x2 * s;
        v[half + i] = x2 * c + x1 * s;
    }
}

#[allow(clippy::too_many_arguments)]
fn attention_block(
    p: Params<'_>,
    x: &mut [f32],
    batch: usize,
    t: usize,
    l: usize,
    cos: &[f32],
    sin: &[f32],
    mut sums: Option<&mut CalibSums>,
) {
    let w = p.weights();
    let cfg = w.config;
    let (d, h, kvh, hd) = (cfg.d, cfg.heads, cfg.kv_heads, cfg.head_dim());
    let kvd = cfg.kvd();
    let an = &w.by_name("attn_norm").data[l * d..(l + 1) * d];
    let rep = h / kvh;
    let scale = 1.0 / (hd as f32).sqrt();
    let rows = batch * t;

    // pre-projection norm over every row, then one GEMM per projection
    let xn = rmsnorm_rows(x, an, rows, d);
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_ATTN, l, &xn, d);
    }
    let mut q = p.linear("wq", l).matmul(&xn, rows);
    let mut k = p.linear("wk", l).matmul(&xn, rows);
    let v = p.linear("wv", l).matmul(&xn, rows);
    // rope, row-parallel (a row's position is r % t)
    parallel_row_bands(&mut q, rows, d, |row0, band| {
        for (i, row) in band.chunks_exact_mut(d).enumerate() {
            let pos = (row0 + i) % t;
            for head in 0..h {
                apply_rope(&mut row[head * hd..(head + 1) * hd], pos, cos, sin);
            }
        }
    });
    parallel_row_bands(&mut k, rows, kvd, |row0, band| {
        for (i, row) in band.chunks_exact_mut(kvd).enumerate() {
            let pos = (row0 + i) % t;
            for head in 0..kvh {
                apply_rope(&mut row[head * hd..(head + 1) * hd], pos, cos, sin);
            }
        }
    });
    // causal attention: each output row depends only on q/k/v, so rows
    // split freely across threads with unchanged per-row FP order
    let mut attn = vec![0.0f32; rows * d];
    parallel_row_bands(&mut attn, rows, d, |row0, band| {
        let mut scores = vec![0.0f32; t];
        for (i, orow) in band.chunks_exact_mut(d).enumerate() {
            let r = row0 + i;
            let (b, pos) = (r / t, r % t);
            for head in 0..h {
                let kv_head = head / rep;
                let qv = &q[r * d + head * hd..r * d + (head + 1) * hd];
                let mut max = f32::MIN;
                for j in 0..=pos {
                    let krow = (b * t + j) * kvd + kv_head * hd;
                    let kv = &k[krow..krow + hd];
                    let s: f32 = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
                    scores[j] = s;
                    max = max.max(s);
                }
                let mut denom = 0.0f32;
                for s in scores[..=pos].iter_mut() {
                    *s = (*s - max).exp();
                    denom += *s;
                }
                let out = &mut orow[head * hd..(head + 1) * hd];
                for j in 0..=pos {
                    let pj = scores[j] / denom;
                    let vrow = (b * t + j) * kvd + kv_head * hd;
                    let vv = &v[vrow..vrow + hd];
                    for i in 0..hd {
                        out[i] += pj * vv[i];
                    }
                }
            }
        }
    });
    // output projection + residual
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_O, l, &attn, d);
    }
    let o = p.linear("wo", l).matmul(&attn, rows);
    residual_add(x, &o, rows, d);
}

fn mlp_block(
    p: Params<'_>,
    x: &mut [f32],
    batch: usize,
    t: usize,
    l: usize,
    mut sums: Option<&mut CalibSums>,
) {
    let w = p.weights();
    let cfg = w.config;
    let (d, dff) = (cfg.d, cfg.dff);
    let mn = &w.by_name("mlp_norm").data[l * d..(l + 1) * d];
    let rows = batch * t;

    let xn = rmsnorm_rows(x, mn, rows, d);
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_MLP, l, &xn, d);
    }
    let mut g = p.linear("w_gate", l).matmul(&xn, rows);
    let u = p.linear("w_up", l).matmul(&xn, rows);
    // silu(g) * u, elementwise row-parallel
    parallel_row_bands(&mut g, rows, dff, |row0, band| {
        let base = row0 * dff;
        for (i, gv) in band.iter_mut().enumerate() {
            let s = *gv / (1.0 + (-*gv).exp());
            *gv = s * u[base + i];
        }
    });
    if let Some(s) = sums.as_deref_mut() {
        s.record_rows(SLOT_DOWN, l, &g, dff);
    }
    let o = p.linear("w_down", l).matmul(&g, rows);
    residual_add(x, &o, rows, d);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lowrank::CompressedModel;
    use crate::model::{ModelConfig, Weights};
    use crate::util::rng::Rng;

    fn setup() -> (Weights, Vec<i32>, usize, usize) {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 3);
        let mut r = Rng::new(5);
        let (b, s) = (cfg.batch, cfg.seq);
        let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
        (w, toks, b, s)
    }

    #[test]
    fn nll_near_uniform_for_random_model() {
        let (w, toks, b, s) = setup();
        let out = nll(&w, &toks, b, s);
        assert_eq!(out.len(), b * (s - 1));
        let mean = out.iter().sum::<f32>() / out.len() as f32;
        let want = (w.config.vocab as f32).ln();
        assert!((mean - want).abs() < 1.0, "mean {mean} vs ln(V) {want}");
    }

    #[test]
    fn causality_future_tokens_do_not_matter() {
        let (w, mut toks, b, s) = setup();
        let a = nll(&w, &toks, b, s);
        // change the last token; all positions except the final prediction
        // target must be unaffected
        toks[s - 1] = (toks[s - 1] + 1).rem_euclid(w.config.vocab as i32);
        let c = nll(&w, &toks, b, s);
        let t = s - 1;
        for pos in 0..t - 1 {
            assert!((a[pos] - c[pos]).abs() < 1e-5, "pos {pos}");
        }
        assert!((a[t - 1] - c[t - 1]).abs() > 1e-7); // target changed
    }

    #[test]
    fn calib_sums_are_symmetric_and_positive() {
        let (w, toks, b, s) = setup();
        let mut sums = CalibSums::new(&w.config);
        accumulate_calib(&w, &toks, b, s, &mut sums);
        accumulate_calib(&w, &toks, b, s, &mut sums);
        assert_eq!(sums.tokens, 2 * b * s);
        for slot in 0..4 {
            let g = &sums.grams[slot][0];
            for i in 0..g.rows {
                assert!(g.at(i, i) >= 0.0);
                for j in 0..g.cols {
                    assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-6, "slot {slot} ({i},{j})");
                }
            }
        }
        assert_eq!(sums.grams[3][0].rows, w.config.dff);
        assert!(sums.absmean[0][0].iter().all(|&v| v >= 0.0));
        // the observer must not perturb the forward itself
        let plain = nll(&w, &toks, b, s);
        let again = nll(&w, &toks, b, s);
        assert_eq!(plain, again);
    }

    #[test]
    fn gqa_runs_and_is_finite() {
        let cfg = ModelConfig::by_name("gqa").unwrap();
        let w = Weights::init(cfg, 4);
        let mut r = Rng::new(6);
        let (b, s) = (1, 16);
        let toks: Vec<i32> = (0..b * s).map(|_| r.below(cfg.vocab) as i32).collect();
        let out = nll(&w, &toks, b, s);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn model_passthrough_is_bit_identical_to_dense() {
        // dense_passthrough resolves every site to the same weight slabs,
        // so the model forward must match the dense forward exactly
        let (w, toks, b, s) = setup();
        let m = CompressedModel::dense_passthrough(w.clone());
        assert_eq!(nll(&w, &toks, b, s), nll_model(&m, &toks, b, s));
        let mut sd = CalibSums::new(&w.config);
        let mut sm = CalibSums::new(&w.config);
        accumulate_calib(&w, &toks, b, s, &mut sd);
        accumulate_calib_model(&m, &toks, b, s, &mut sm);
        assert_eq!(sd.tokens, sm.tokens);
        for slot in 0..4 {
            for l in 0..w.config.layers {
                assert_eq!(sd.grams[slot][l].data, sm.grams[slot][l].data);
                assert_eq!(sd.absmean[slot][l], sm.absmean[slot][l]);
            }
        }
    }
}
