//! Truncation-aware whitening (SVD-LLM / Basis-Sharing style).
//!
//! For y = x·W with calibration Gram G = E[xᵀx] = L·Lᵀ (Cholesky), the
//! activation-weighted reconstruction loss is
//!     E‖x(W−Ŵ)‖² = ‖Lᵀ(W−Ŵ)‖²_F,
//! so the optimal rank-k Ŵ is S⁻¹·(S·W)_k with S = Lᵀ. The paper writes
//! this as "SSᵀ = cholesky(XᵀX)" (§3.1); n=1 grouping reduces exactly to
//! SVD-LLM. Grouped variants share one S computed from the summed Gram of
//! the group's layers (DESIGN.md "Method conventions"). The whitened
//! matrix S·W then goes through `linalg::svd` — whose Gram eigensolve is
//! the blocked-parallel Jacobi — so whitening cost is profiled under the
//! `whiten` stage and the decomposition under `eigen_sweep`/`eigen_sort`.

use crate::linalg::{cholesky_jitter, solve_lower_t};
use crate::tensor::MatF;
use crate::util::profile::{self, Stage};

/// Whitener for one group: holds the Cholesky factor L (S = Lᵀ).
pub struct Whitener {
    pub l: MatF,
    pub jitter: f64,
}

impl Whitener {
    /// Build from a (mean) input Gram matrix.
    pub fn from_gram(gram: &MatF) -> Self {
        let (l, jitter) = profile::time(Stage::Whiten, || cholesky_jitter(gram));
        Self { l, jitter }
    }

    /// S·W = Lᵀ·W.
    pub fn apply(&self, w: &MatF) -> MatF {
        self.l.t_matmul(w)
    }

    /// S⁻¹·M = L⁻ᵀ·M (triangular solve; no explicit inverse).
    pub fn unapply(&self, m: &MatF) -> MatF {
        solve_lower_t(&self.l, m)
    }
}

/// Identity whitener helper for diagonal scalings (FWSVD/ASVD):
/// returns (scaled rows of W, inverse scales) for S = diag(s).
pub fn diag_scale(w: &MatF, scales: &[f64]) -> (MatF, Vec<f64>) {
    assert_eq!(w.rows, scales.len());
    let mut out = w.clone();
    let mut inv = Vec::with_capacity(scales.len());
    for (r, &s) in scales.iter().enumerate() {
        let s = s.max(1e-12);
        out.scale_row(r, s);
        inv.push(1.0 / s);
    }
    (out, inv)
}

/// Apply diag(inv) on the left: rows of m scaled by inv.
pub fn diag_unscale(m: &mut MatF, inv: &[f64]) {
    assert_eq!(m.rows, inv.len());
    for (r, &s) in inv.iter().enumerate() {
        m.scale_row(r, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, m: usize, n: usize) -> MatF {
        MatF::from_vec(m, n, (0..m * n).map(|_| rng.normal()).collect())
    }

    fn random_gram(rng: &mut Rng, n: usize, samples: usize) -> MatF {
        let x = random(rng, samples, n);
        let mut g = x.t_matmul(&x);
        g.scale(1.0 / samples as f64);
        g
    }

    #[test]
    fn apply_unapply_roundtrip() {
        let mut rng = Rng::new(0);
        let g = random_gram(&mut rng, 16, 64);
        let wh = Whitener::from_gram(&g);
        let w = random(&mut rng, 16, 24);
        let rec = wh.unapply(&wh.apply(&w));
        let err = rec.sub(&w).frob_norm() / w.frob_norm();
        assert!(err < 1e-9, "{err}");
    }

    #[test]
    fn whitened_truncation_beats_plain_on_activation_loss() {
        // the whole point of SVD-LLM: for anisotropic activations, the
        // whitened truncation has lower E||x(W-Ŵ)||² than plain SVD
        let mut rng = Rng::new(1);
        let n = 24;
        // anisotropic Gram: strong low-dim structure
        let mut x = random(&mut rng, 200, n);
        for r in 0..200 {
            for c in 0..n {
                *x.at_mut(r, c) *= 1.0 / (1.0 + c as f64);
            }
        }
        let mut g = x.t_matmul(&x);
        g.scale(1.0 / 200.0);
        let w = random(&mut rng, n, 32);
        let k = 8;

        let wh = Whitener::from_gram(&g);
        let sw = wh.apply(&w);
        let whitened_hat = wh.unapply(&svd(&sw).reconstruct(k));
        let plain_hat = svd(&w).reconstruct(k);

        let act_loss = |what: &MatF| {
            // ||Lᵀ (W - Ŵ)||_F
            let diff = w.sub(what);
            wh.l.t_matmul(&diff).frob_norm()
        };
        let lw = act_loss(&whitened_hat);
        let lp = act_loss(&plain_hat);
        assert!(lw <= lp + 1e-9, "whitened {lw} vs plain {lp}");
    }

    #[test]
    fn diag_scale_roundtrip() {
        let mut rng = Rng::new(2);
        let w = random(&mut rng, 10, 7);
        let scales: Vec<f64> = (0..10).map(|i| 0.5 + i as f64).collect();
        let (mut sw, inv) = diag_scale(&w, &scales);
        diag_unscale(&mut sw, &inv);
        let err = sw.sub(&w).frob_norm();
        assert!(err < 1e-12);
    }
}
