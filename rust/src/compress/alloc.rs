//! Rank allocation: the paper's Lagrange-multiplier scheme (§3.2, App B.3)
//! and the β-rebalance across attention types (§3.3).
//!
//! R_eff comes from the σ² spectrum of each group's SVD (the blocked
//! Jacobi eigensolve in `linalg`), so allocation latency is bounded by
//! eigensolver throughput — and allocations are bit-identical for any
//! `--threads` value, making rank plans reproducible across machines.
//!
//! Per weight type with G groups of effective rank R_eff(g), parameter cost
//! per rank ω = d1 + n·d2, and budget T = (1−θ)·(type params):
//!     min Σ R_eff(g)/k_g   s.t.  Σ k_g·ω = T
//!     ⟹ k_g = T / (Σ_j √(R_eff(j)·ω)) · √(R_eff(g)/ω)     (Eq. 19)
//! Integerization floors, clamps to [1, kmax_g], then greedily spends the
//! leftover budget where the marginal loss reduction R/(k(k+1)) is largest.

/// A group's allocation inputs.
#[derive(Clone, Debug)]
pub struct GroupSpec {
    pub reff: f64,
    /// params per unit rank (d1 + n·d2)
    pub omega: usize,
    /// rank cap (min(d1, n·d2), and never above group break-even)
    pub kmax: usize,
}

/// Closed-form Lagrange allocation + greedy integer repair.
/// `budget_params` is the parameter budget for this type.
pub fn lagrange_alloc(groups: &[GroupSpec], budget_params: f64) -> Vec<usize> {
    assert!(!groups.is_empty());
    let denom: f64 = groups
        .iter()
        .map(|g| (g.reff.max(1e-12) * g.omega as f64).sqrt())
        .sum();
    let mut ks: Vec<usize> = groups
        .iter()
        .map(|g| {
            let k = budget_params / denom * (g.reff.max(1e-12) / g.omega as f64).sqrt();
            (k.floor() as usize).clamp(1, g.kmax.max(1))
        })
        .collect();
    // greedy repair toward the budget
    let spent =
        |ks: &[usize]| -> f64 { ks.iter().zip(groups).map(|(&k, g)| (k * g.omega) as f64).sum() };
    // spend leftover where marginal gain d(R/k) = R/(k(k+1)) is largest
    loop {
        let left = budget_params - spent(&ks);
        let mut best: Option<(usize, f64)> = None;
        for (i, g) in groups.iter().enumerate() {
            if ks[i] < g.kmax && (g.omega as f64) <= left {
                let gain = g.reff / (ks[i] * (ks[i] + 1)) as f64 / g.omega as f64;
                if best.map(|(_, b)| gain > b).unwrap_or(true) {
                    best = Some((i, gain));
                }
            }
        }
        match best {
            Some((i, _)) => ks[i] += 1,
            None => break,
        }
    }
    // trim if clamping pushed us over budget
    while spent(&ks) > budget_params {
        // remove where the loss increase R/(k(k-1)) is smallest
        let mut best: Option<(usize, f64)> = None;
        for (i, g) in groups.iter().enumerate() {
            if ks[i] > 1 {
                let cost = g.reff / (ks[i] * (ks[i] - 1)) as f64 / g.omega as f64;
                if best.map(|(_, b)| cost < b).unwrap_or(true) {
                    best = Some((i, cost));
                }
            }
        }
        match best {
            Some((i, _)) => ks[i] -= 1,
            None => break,
        }
    }
    ks
}

/// Uniform allocation (the baselines): every group of a type gets the same
/// rank implied by the target ratio, k = (1−θ)·n·d1·d2 / (d1 + n·d2).
pub fn uniform_rank(d1: usize, d2: usize, n: usize, ratio: f64) -> usize {
    let k = (1.0 - ratio) * (n * d1 * d2) as f64 / (d1 + n * d2) as f64;
    (k.floor() as usize).max(1)
}

/// β-rebalance (§3.3): move a β fraction of the Q and K rank budget to V.
///
/// The paper's Eqs. (9)-(12) conserve *rank counts*, which equals parameter
/// conservation when ω_q = ω_k = ω_v (MHA). On GQA models ω differs, so we
/// transfer *parameters*: t_v = β·(Σk_Q·ω_q + Σk_K·ω_k) / (G·ω_v), which
/// reduces to Eq. (11) in the MHA case. Returns (q, k, v) allocations.
pub fn beta_rebalance(
    beta: f64,
    kq: &[usize],
    kk: &[usize],
    kv: &[usize],
    omega_q: usize,
    omega_k: usize,
    omega_v: usize,
    kmax_v: &[usize],
) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
    assert!((0.0..1.0).contains(&beta));
    let g = kv.len();
    assert_eq!(kq.len(), g);
    assert_eq!(kk.len(), g);
    let mut extracted_params = 0f64;
    let scale = |ks: &[usize], omega: usize, extracted: &mut f64| -> Vec<usize> {
        ks.iter()
            .map(|&k| {
                let keep = (((1.0 - beta) * k as f64).floor() as usize).max(1);
                *extracted += ((k - keep) * omega) as f64;
                keep
            })
            .collect()
    };
    let q2 = scale(kq, omega_q, &mut extracted_params);
    let k2 = scale(kk, omega_k, &mut extracted_params);
    let t = (extracted_params / (g as f64 * omega_v as f64)).floor() as usize;
    let v2: Vec<usize> = kv
        .iter()
        .zip(kmax_v)
        .map(|(&k, &cap)| (k + t).min(cap))
        .collect();
    (q2, k2, v2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs(reffs: &[f64], omega: usize, kmax: usize) -> Vec<GroupSpec> {
        reffs.iter().map(|&r| GroupSpec { reff: r, omega, kmax }).collect()
    }

    #[test]
    fn budget_is_respected_and_nearly_exhausted() {
        let gs = specs(&[100.0, 400.0, 900.0, 400.0], 256, 128);
        let budget = 60_000.0;
        let ks = lagrange_alloc(&gs, budget);
        let spent: usize = ks.iter().map(|&k| k * 256).sum();
        assert!(spent as f64 <= budget);
        assert!(spent as f64 > budget - 256.0, "spent {spent}");
    }

    #[test]
    fn ranks_follow_sqrt_reff() {
        // R ratio 4:1 should give k ratio ~2:1 (Eq. 6)
        let gs = specs(&[400.0, 100.0], 100, 10_000);
        let ks = lagrange_alloc(&gs, 30_000.0);
        let ratio = ks[0] as f64 / ks[1] as f64;
        assert!((ratio - 2.0).abs() < 0.1, "{ks:?}");
    }

    #[test]
    fn higher_omega_gets_fewer_ranks() {
        let gs = vec![
            GroupSpec { reff: 100.0, omega: 100, kmax: 10_000 },
            GroupSpec { reff: 100.0, omega: 400, kmax: 10_000 },
        ];
        let ks = lagrange_alloc(&gs, 50_000.0);
        assert!(ks[0] > ks[1], "{ks:?}");
        // proportionality ~ 1/sqrt(omega): ratio 2
        let ratio = ks[0] as f64 / ks[1] as f64;
        assert!((ratio - 2.0).abs() < 0.2, "{ks:?}");
    }

    #[test]
    fn kmax_clamp_redistributes() {
        let gs = vec![
            GroupSpec { reff: 10_000.0, omega: 10, kmax: 5 }, // tiny cap
            GroupSpec { reff: 1.0, omega: 10, kmax: 10_000 },
        ];
        let ks = lagrange_alloc(&gs, 1_000.0);
        assert_eq!(ks[0], 5);
        // leftover goes to the other group
        assert!(ks[1] >= 90, "{ks:?}");
    }

    #[test]
    fn uniform_rank_matches_ratio() {
        // params(k) = k (d1 + n d2) ≈ (1-θ) n d1 d2
        let k = uniform_rank(192, 192, 2, 0.2);
        let params = k * (192 + 2 * 192);
        let dense = 2 * 192 * 192;
        let achieved = 1.0 - params as f64 / dense as f64;
        assert!((achieved - 0.2).abs() < 0.02, "{achieved}");
    }

    #[test]
    fn beta_rebalance_conserves_params_mha() {
        let kq = vec![40, 50, 60];
        let kk = vec![30, 30, 30];
        let kv = vec![50, 50, 50];
        let omega = 256;
        let before: usize = kq.iter().chain(&kk).chain(&kv).map(|k| k * omega).sum();
        let (q2, k2, v2) =
            beta_rebalance(0.3, &kq, &kk, &kv, omega, omega, omega, &[10_000; 3]);
        let after: usize = q2.iter().chain(&k2).chain(&v2).map(|k| k * omega).sum();
        // conservation up to flooring (±G·ω)
        assert!(after <= before);
        assert!(before - after <= 3 * omega, "{before} -> {after}");
        assert!(v2.iter().zip(&kv).all(|(a, b)| a >= b));
        assert!(q2.iter().zip(&kq).all(|(a, b)| a <= b));
    }

    #[test]
    fn beta_zero_is_identity() {
        let kq = vec![40, 50];
        let (q2, k2, v2) = beta_rebalance(
            0.0,
            &kq,
            &[30, 30],
            &[20, 20],
            100,
            100,
            100,
            &[1000, 1000],
        );
        assert_eq!(q2, kq);
        assert_eq!(k2, vec![30, 30]);
        assert_eq!(v2, vec![20, 20]);
    }

    #[test]
    fn beta_rebalance_gqa_param_transfer() {
        // GQA: V is slimmer (omega_v < omega_q) -> V gains MORE ranks per
        // extracted Q rank, params still conserved
        let (q2, _k2, v2) = beta_rebalance(
            0.4,
            &[100, 100],
            &[100, 100],
            &[100, 100],
            400, // omega_q
            160, // omega_k (slim)
            160, // omega_v (slim)
            &[10_000; 2],
        );
        let extracted = (100 - q2[0]) * 400 * 2 + (100 - 100.min(60)) * 0; // q side dominates
        let gained: usize = v2.iter().map(|&k| (k - 100) * 160).sum();
        // gained <= extracted (flooring) and same order
        assert!(gained > 0);
        let _ = extracted;
    }
}
