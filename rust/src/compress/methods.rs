//! Method implementations over shared machinery.
//!
//! One SVD per (type, group) feeds both the effective-rank statistics and
//! the truncated factors, so a full compression run factorizes each group
//! exactly once. The six methods differ only in (scaling, grouping, rank
//! decision) — see the table in `compress::mod`.

use std::collections::BTreeMap;

use anyhow::Result;

use super::alloc::{beta_rebalance, lagrange_alloc, uniform_rank, GroupSpec};
use super::whiten::{diag_scale, diag_unscale, Whitener};
use super::{layer_groups, CompressOpts, Method};
use crate::calib::CalibStats;
use crate::linalg::effective_rank;
use crate::linalg::svd::{svd, Svd};
use crate::model::lowrank::{CompressedModel, GroupFactors, TypeRep};
use crate::model::{ModelConfig, Weights, COMPRESSIBLE};
use crate::tensor::MatF;
use crate::util::parallel::parallel_map;
use crate::util::profile::{self, Stage};

/// Types eligible for cross-layer grouping (the paper groups Q,K,V,up,gate
/// but never W_down / W_O — §4.1 implementation details).
pub const GROUPABLE: [&str; 5] = ["wq", "wk", "wv", "w_gate", "w_up"];

/// How one group's matrix was scaled before SVD (to invert on B).
enum Scaler {
    None,
    White(Whitener),
    Diag(Vec<f64>),
}

/// One factorized group, pre-truncation.
pub struct GroupSvd {
    pub start: usize,
    pub n: usize,
    pub svd: Svd,
    pub reff: f64,
    scaler: Scaler,
}

impl GroupSvd {
    /// Truncate to rank k and undo the scaling on the basis side.
    /// (Profiled as the Truncate stage — includes the unwhitening solve.)
    pub fn factors(&self, k: usize, d2: usize) -> GroupFactors {
        let _t = profile::ScopedTimer::new(Stage::Truncate);
        let (b_scaled, c) = self.svd.factors(k);
        let b = match &self.scaler {
            Scaler::None => b_scaled,
            Scaler::White(w) => w.unapply(&b_scaled),
            Scaler::Diag(inv) => {
                let mut b = b_scaled;
                diag_unscale(&mut b, inv);
                b
            }
        };
        let cs = c
            .hsplit(self.n)
            .into_iter()
            .map(|m| m.to_f32())
            .collect::<Vec<_>>();
        debug_assert!(cs.iter().all(|m| m.cols == d2));
        GroupFactors::new(self.start, b.to_f32(), cs)
    }
}

/// Effective group size for a type under the method + GQA policy (§3.4).
pub fn group_size(cfg: &ModelConfig, typ: &str, opts: &CompressOpts) -> usize {
    if !opts.method.groups() || !GROUPABLE.contains(&typ) {
        return 1;
    }
    if opts.method == Method::DRank && cfg.is_gqa() && opts.gqa_policy {
        return 1; // paper §3.4: grouping hurts slimmed-KV models
    }
    opts.group_layers
}

/// Scaled SVD of one group of `typ` spanning layers [start, start+n).
pub fn group_svd(
    weights: &Weights,
    stats: &CalibStats,
    typ: &str,
    start: usize,
    n: usize,
    opts: &CompressOpts,
) -> GroupSvd {
    let pidx = ModelConfig::param_index(typ);
    let tensor = &weights.tensors[pidx];
    let mats: Vec<MatF> = (start..start + n)
        .map(|l| MatF::from_f32(&tensor.layer_mat(l)))
        .collect();
    let refs: Vec<&MatF> = mats.iter().collect();
    let w_cat = MatF::hcat(&refs);

    let (scaled, scaler) = match opts.method {
        Method::PlainSvd => (w_cat, Scaler::None),
        Method::Fwsvd => {
            // Fisher row weights: rows of W weighted by sqrt(Σ_batch g²)
            let d1 = w_cat.rows;
            let mut f = vec![0.0f64; d1];
            for l in start..start + n {
                if let Some(rows) = stats.fisher_rows(typ, l) {
                    for i in 0..d1 {
                        f[i] += rows[i];
                    }
                }
            }
            let mean = f.iter().sum::<f64>() / d1 as f64;
            let scales: Vec<f64> =
                f.iter().map(|&x| (x + mean * 1e-3 + 1e-12).sqrt()).collect();
            let (sw, inv) = diag_scale(&w_cat, &scales);
            (sw, Scaler::Diag(inv))
        }
        Method::Asvd => {
            // activation-aware diagonal: S_ii = (E|x_i|)^α
            let d1 = w_cat.rows;
            let mut a = vec![0.0f64; d1];
            for l in start..start + n {
                let am = stats.absmean(typ, l);
                for i in 0..d1 {
                    a[i] += am[i] / n as f64;
                }
            }
            let scales: Vec<f64> =
                a.iter().map(|&x| x.max(1e-9).powf(opts.asvd_alpha)).collect();
            let (sw, inv) = diag_scale(&w_cat, &scales);
            (sw, Scaler::Diag(inv))
        }
        Method::SvdLlm | Method::BasisSharing | Method::DRank => {
            // shared whitener from the group-mean input Gram
            let d1 = w_cat.rows;
            let g = profile::time(Stage::Gram, || {
                let mut g = MatF::zeros(d1, d1);
                for l in start..start + n {
                    g.add_assign(stats.gram(typ, l));
                }
                g.scale(1.0 / n as f64);
                g
            });
            let wh = Whitener::from_gram(&g);
            let sw = profile::time(Stage::Whiten, || wh.apply(&w_cat));
            (sw, Scaler::White(wh))
        }
    };
    // the blocked Jacobi eigensolve inside `svd` is itself pool-parallel
    // (and still bit-deterministic), so a single large group scales even
    // when the per-group fan-out in `type_svds` has spare threads
    let decomp = svd(&scaled);
    let reff = effective_rank(&decomp.s);
    GroupSvd { start, n, svd: decomp, reff, scaler }
}

/// All group SVDs of one type, decomposed in parallel (each group is an
/// independent work unit; collection is index-ordered, so the result is
/// bit-identical to the sequential loop).
pub fn type_svds(
    weights: &Weights,
    stats: &CalibStats,
    typ: &str,
    opts: &CompressOpts,
) -> Vec<GroupSvd> {
    let cfg = weights.config;
    let n = group_size(&cfg, typ, opts);
    parallel_map(layer_groups(cfg.layers, n), |(start, len)| {
        group_svd(weights, stats, typ, start, len, opts)
    })
}

/// Group SVDs for every compressible type as ONE flat parallel work list.
///
/// Flattening across types load-balances better than per-type fan-out: the
/// wide `w_gate`/`w_up` decompositions interleave with the cheap attention
/// ones instead of serializing behind them. Results are reassembled in
/// `COMPRESSIBLE`/group order, so the map is bit-identical to calling
/// [`type_svds`] per type sequentially.
pub fn all_type_svds(
    weights: &Weights,
    stats: &CalibStats,
    opts: &CompressOpts,
) -> BTreeMap<String, Vec<GroupSvd>> {
    let cfg = weights.config;
    let mut items: Vec<(&'static str, usize, usize)> = Vec::new();
    for typ in COMPRESSIBLE {
        let n = group_size(&cfg, typ, opts);
        for (start, len) in layer_groups(cfg.layers, n) {
            items.push((typ, start, len));
        }
    }
    let decomposed = parallel_map(items.clone(), |(typ, start, len)| {
        group_svd(weights, stats, typ, start, len, opts)
    });
    let mut out: BTreeMap<String, Vec<GroupSvd>> = BTreeMap::new();
    for typ in COMPRESSIBLE {
        out.insert(typ.to_string(), Vec::new());
    }
    for ((typ, _, _), g) in items.into_iter().zip(decomposed) {
        out.get_mut(typ).unwrap().push(g);
    }
    out
}

/// Rank cap for a group: never exceed the group's break-even point.
fn group_kmax(d1: usize, d2: usize, n: usize) -> usize {
    let even = (n * d1 * d2) / (d1 + n * d2);
    even.min(d1).min(n * d2).max(1)
}

/// The allocated ranks for every type (the plan the benches report).
pub type RankPlan = BTreeMap<String, Vec<usize>>;

/// Decide per-group ranks for every type.
pub fn plan_ranks(
    cfg: &ModelConfig,
    svds: &BTreeMap<String, Vec<GroupSvd>>,
    opts: &CompressOpts,
) -> RankPlan {
    let mut plan = RankPlan::new();
    for typ in COMPRESSIBLE {
        let (d1, d2) = cfg.matrix_dims(typ);
        let groups = &svds[typ];
        let ks: Vec<usize> = if opts.method == Method::DRank {
            let budget = (1.0 - opts.ratio) * (cfg.layers * d1 * d2) as f64;
            let specs: Vec<GroupSpec> = groups
                .iter()
                .map(|g| GroupSpec {
                    reff: g.reff,
                    omega: d1 + g.n * d2,
                    kmax: group_kmax(d1, d2, g.n),
                })
                .collect();
            lagrange_alloc(&specs, budget)
        } else {
            groups
                .iter()
                .map(|g| uniform_rank(d1, d2, g.n, opts.ratio).min(group_kmax(d1, d2, g.n)))
                .collect()
        };
        plan.insert(typ.to_string(), ks);
    }
    // β-rebalance Q,K -> V (D-Rank §3.3)
    if opts.method == Method::DRank && opts.beta > 0.0 {
        let (d1q, d2q) = cfg.matrix_dims("wq");
        let (d1k, d2k) = cfg.matrix_dims("wk");
        let (d1v, d2v) = cfg.matrix_dims("wv");
        // each ω from that type's OWN group size: Q, K, V can be grouped
        // differently (e.g. a per-type grouping override), and pricing K/V
        // ranks with Q's n would misallocate the moved budget
        let nq = svds["wq"].first().map(|g| g.n).unwrap_or(1);
        let nk = svds["wk"].first().map(|g| g.n).unwrap_or(1);
        let nv = svds["wv"].first().map(|g| g.n).unwrap_or(1);
        let kmax_v: Vec<usize> =
            svds["wv"].iter().map(|g| group_kmax(d1v, d2v, g.n)).collect();
        let (q2, k2, v2) = beta_rebalance(
            opts.beta,
            &plan["wq"],
            &plan["wk"],
            &plan["wv"],
            d1q + nq * d2q,
            d1k + nk * d2k,
            d1v + nv * d2v,
            &kmax_v,
        );
        plan.insert("wq".into(), q2);
        plan.insert("wk".into(), k2);
        plan.insert("wv".into(), v2);
    }
    plan
}

/// Full compression run: one SVD per group, allocation, truncation.
/// Returns the compressed model and the rank plan actually used.
pub fn compress(
    weights: &Weights,
    stats: &CalibStats,
    opts: &CompressOpts,
) -> Result<(CompressedModel, RankPlan)> {
    opts.validate()?;
    let cfg = weights.config;
    let svds = all_type_svds(weights, stats, opts);
    let plan = plan_ranks(&cfg, &svds, opts);
    let mut model = CompressedModel::dense_passthrough(weights.clone());
    for typ in COMPRESSIBLE {
        let (d1, d2) = cfg.matrix_dims(typ);
        let groups = &svds[typ];
        let ks = &plan[typ];
        // keep dense if factoring would not shrink this type
        let factored_params: usize = groups
            .iter()
            .zip(ks)
            .map(|(g, &k)| k * (d1 + g.n * d2))
            .sum();
        if factored_params >= cfg.layers * d1 * d2 {
            continue;
        }
        let items: Vec<(usize, usize)> = ks.iter().copied().enumerate().collect();
        let reps: Vec<GroupFactors> =
            parallel_map(items, |(gi, k)| groups[gi].factors(k, d2));
        model.reps.insert(typ.to_string(), TypeRep::Factored(reps));
    }
    Ok((model, plan))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::matmul_f32;

    fn setup(name: &str) -> (Weights, CalibStats) {
        let cfg = ModelConfig::by_name(name).unwrap();
        let w = Weights::init(cfg, 11);
        let stats = CalibStats::synthetic(&cfg, 12);
        (w, stats)
    }

    fn opts(method: Method, ratio: f64, n: usize) -> CompressOpts {
        CompressOpts { method, ratio, group_layers: n, ..Default::default() }
    }

    #[test]
    fn every_method_hits_target_ratio() {
        let (w, stats) = setup("tiny");
        for method in [
            Method::PlainSvd,
            Method::Fwsvd,
            Method::Asvd,
            Method::SvdLlm,
            Method::BasisSharing,
            Method::DRank,
        ] {
            let (model, _) = compress(&w, &stats, &opts(method, 0.3, 2)).unwrap();
            let got = model.achieved_ratio();
            assert!(
                (got - 0.3).abs() < 0.05,
                "{}: achieved {got:.3} vs 0.3",
                method.name()
            );
        }
    }

    #[test]
    fn reconstruction_error_grows_with_ratio() {
        // random Gaussian weights have flat spectra (truncation worst case),
        // so assert the meaningful invariants: error is bounded and strictly
        // monotone in the compression ratio.
        let (w, stats) = setup("tiny");
        let rel_err = |ratio: f64| -> f32 {
            let (model, _) = compress(&w, &stats, &opts(Method::SvdLlm, ratio, 1)).unwrap();
            let dense = model.to_dense();
            let orig = w.by_name("wq").layer_mat(0);
            let rec = dense.by_name("wq").layer_mat(0);
            let num: f32 =
                orig.data.iter().zip(&rec.data).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f32 = orig.data.iter().map(|a| a * a).sum();
            (num / den).sqrt()
        };
        let e1 = rel_err(0.1);
        let e5 = rel_err(0.5);
        assert!(e1 < 0.75, "rel err at 10%: {e1}");
        assert!(e5 > e1, "monotonicity: {e1} vs {e5}");
        assert!(e5 < 1.0);
    }

    #[test]
    fn factors_reconstruct_group_structure() {
        let (w, stats) = setup("tiny");
        let (model, plan) = compress(&w, &stats, &opts(Method::BasisSharing, 0.2, 2)).unwrap();
        // tiny has 2 layers -> one group for groupable types
        match &model.reps["wq"] {
            TypeRep::Factored(groups) => {
                assert_eq!(groups.len(), 1);
                assert_eq!(groups[0].cs.len(), 2);
                assert_eq!(groups[0].rank(), plan["wq"][0]);
                // B is shared: both layers reconstruct from the same basis
                let r0 = matmul_f32(&groups[0].b, &groups[0].cs[0]);
                let r1 = matmul_f32(&groups[0].b, &groups[0].cs[1]);
                assert_ne!(r0.data, r1.data);
            }
            _ => panic!("wq not factored"),
        }
        // non-groupable types stay n=1
        match &model.reps["w_down"] {
            TypeRep::Factored(groups) => assert_eq!(groups.len(), 2),
            _ => panic!("w_down not factored"),
        }
    }

    #[test]
    fn drank_allocates_more_rank_to_higher_reff() {
        let (w, stats) = setup("m");
        let o = opts(Method::DRank, 0.3, 2);
        let svds = type_svds(&w, &stats, "wv", &o);
        let mut plan_svds = BTreeMap::new();
        for t in COMPRESSIBLE {
            plan_svds.insert(t.to_string(), type_svds(&w, &stats, t, &o));
        }
        let plan = plan_ranks(&w.config, &plan_svds, &o);
        // within wv: ranks ordered like sqrt(reff) (weak check: argmax match)
        let reffs: Vec<f64> = svds.iter().map(|g| g.reff).collect();
        let ks = &plan["wv"];
        let max_r = reffs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let max_k = ks.iter().enumerate().max_by_key(|x| *x.1).unwrap().0;
        assert_eq!(max_r, max_k, "reffs {reffs:?} ks {ks:?}");
    }

    #[test]
    fn drank_beta_moves_budget_to_v() {
        let (w, stats) = setup("m");
        let mut o = opts(Method::DRank, 0.3, 2);
        o.beta = 0.0;
        let (_, plan0) = compress(&w, &stats, &o).unwrap();
        o.beta = 0.4;
        let (model, plan1) = compress(&w, &stats, &o).unwrap();
        let sum = |p: &RankPlan, t: &str| p[t].iter().sum::<usize>();
        assert!(sum(&plan1, "wv") > sum(&plan0, "wv"));
        assert!(sum(&plan1, "wq") < sum(&plan0, "wq"));
        // overall budget still respected
        assert!((model.achieved_ratio() - 0.3).abs() < 0.05);
    }

    #[test]
    fn gqa_policy_forces_n1() {
        let cfg = ModelConfig::by_name("gqa").unwrap();
        let o = opts(Method::DRank, 0.2, 4);
        assert_eq!(group_size(&cfg, "wk", &o), 1);
        let mut o2 = o.clone();
        o2.gqa_policy = false;
        assert_eq!(group_size(&cfg, "wk", &o2), 4);
        // basis sharing ignores the policy (it's a D-Rank feature)
        let o3 = opts(Method::BasisSharing, 0.2, 4);
        assert_eq!(group_size(&cfg, "wk", &o3), 4);
        // never grouped types
        assert_eq!(group_size(&cfg, "wo", &o2), 1);
        assert_eq!(group_size(&cfg, "w_down", &o3), 1);
    }

    #[test]
    fn whitened_beats_plain_svd_on_activation_loss() {
        // end-to-end analog of the SVD-LLM claim, at the model level:
        // mean activation-weighted reconstruction error over wq layers
        let (w, stats) = setup("tiny");
        let act_err = |model: &CompressedModel| -> f64 {
            let dense = model.to_dense();
            let cfg = w.config;
            let mut total = 0.0;
            for l in 0..cfg.layers {
                let orig = MatF::from_f32(&w.by_name("wq").layer_mat(l));
                let rec = MatF::from_f32(&dense.by_name("wq").layer_mat(l));
                let diff = orig.sub(&rec);
                let wh = crate::compress::whiten::Whitener::from_gram(stats.gram("wq", l));
                total += wh.l.t_matmul(&diff).frob_norm();
            }
            total
        };
        let (plain, _) = compress(&w, &stats, &opts(Method::PlainSvd, 0.4, 1)).unwrap();
        let (whitened, _) = compress(&w, &stats, &opts(Method::SvdLlm, 0.4, 1)).unwrap();
        assert!(act_err(&whitened) <= act_err(&plain) * 1.02);
    }

    #[test]
    fn effective_ranks_table_shape() {
        let (w, stats) = setup("m");
        let r = effective_ranks_table(&w, &stats, "wv", 2);
        assert_eq!(r.len(), 3); // 6 layers / 2
        assert!(r.iter().all(|&x| x > 0.0));
    }
}

/// Effective ranks per group for a type (Table 1 / Figure 2 data).
pub fn effective_ranks_table(
    weights: &Weights,
    stats: &CalibStats,
    typ: &str,
    group_layers: usize,
) -> Vec<f64> {
    let opts = CompressOpts {
        method: Method::DRank,
        group_layers,
        gqa_policy: false,
        ..Default::default()
    };
    let cfg = weights.config;
    layer_groups(cfg.layers, group_layers)
        .into_iter()
        .map(|(s, n)| group_svd(weights, stats, typ, s, n, &opts).reff)
        .collect()
}
