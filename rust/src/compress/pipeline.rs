//! End-to-end compression pipeline, including the sequential compensation
//! the paper enables at ratios ≥ 40% ("we adaptively update the downstream
//! layer weights using the deviated inputs", §4.1).
//!
//! Compensated flow: layer blocks are compressed front-to-back; before each
//! block, calibration re-runs with the *already-compressed* prefix (via
//! dense reconstruction), so downstream whitening sees the deviated
//! activations. Rank allocation is decided once up front from the clean
//! statistics (the deviation shifts whitening, not the information-density
//! ordering).
//!
//! Recalibration is a pluggable seam ([`compensated_with`]): production
//! streams batches through the AOT calib artifact over PJRT, while the
//! reference path ([`compress_model_reference`]) uses the instrumented
//! pure-Rust forward — so the whole pipeline runs (and is tested) with no
//! `artifacts/` directory.

use std::collections::BTreeMap;

use anyhow::Result;

use super::methods::{compress, group_size, plan_ranks, type_svds, RankPlan};
use super::{layer_groups, CompressOpts};
use crate::calib::{self, CalibOpts, CalibStats};
use crate::data::DataBundle;
use crate::model::lowrank::{CompressedModel, GroupFactors, TypeRep};
use crate::model::{Weights, COMPRESSIBLE};
use crate::runtime::Engine;

/// Calibrate + compress in one call (PJRT calibration path).
pub fn compress_model(
    engine: &Engine,
    weights: &Weights,
    data: &DataBundle,
    copts: &CalibOpts,
    opts: &CompressOpts,
) -> Result<(CompressedModel, RankPlan)> {
    let stats = calib::run(engine, weights, data, copts)?;
    compress_with_stats(engine, weights, data, stats, copts, opts)
}

/// Calibrate + compress entirely in pure Rust (no artifacts, no PJRT):
/// statistics come from the instrumented reference forward, and the
/// compensated path recalibrates the same way.
pub fn compress_model_reference(
    weights: &Weights,
    data: &DataBundle,
    copts: &CalibOpts,
    opts: &CompressOpts,
) -> Result<(CompressedModel, RankPlan)> {
    let stats = calib::run_reference(weights, data, copts)?;
    if !opts.compensate {
        return compress(weights, &stats, opts);
    }
    compensated_with(weights, stats, opts, |w| calib::run_reference(w, data, copts))
}

/// Compress given pre-computed statistics; dispatches on compensation.
pub fn compress_with_stats(
    engine: &Engine,
    weights: &Weights,
    data: &DataBundle,
    stats: CalibStats,
    copts: &CalibOpts,
    opts: &CompressOpts,
) -> Result<(CompressedModel, RankPlan)> {
    if !opts.compensate {
        return compress(weights, &stats, opts);
    }
    compensated_with(weights, stats, opts, |w| calib::run(engine, w, data, copts))
}

/// The §4.1 sequential-compensation loop over a pluggable recalibration
/// provider: `recalib` is invoked with the partially-compressed model
/// (reconstructed dense) before each block after the first.
pub fn compensated_with(
    weights: &Weights,
    stats0: CalibStats,
    opts: &CompressOpts,
    mut recalib: impl FnMut(&Weights) -> Result<CalibStats>,
) -> Result<(CompressedModel, RankPlan)> {
    let cfg = weights.config;
    // 1. allocation from clean statistics
    let mut svds = BTreeMap::new();
    for typ in COMPRESSIBLE {
        svds.insert(typ.to_string(), type_svds(weights, &stats0, typ, opts));
    }
    let plan = plan_ranks(&cfg, &svds, opts);
    drop(svds); // whitening will be redone per block with fresh stats

    // 2. block-by-block compression with recalibration. Block granularity is
    //    the grouping stride (max over types so group boundaries align).
    let stride = COMPRESSIBLE
        .iter()
        .map(|t| group_size(&cfg, t, opts))
        .max()
        .unwrap_or(1);
    let blocks = layer_groups(cfg.layers, stride);

    let mut model = CompressedModel::dense_passthrough(weights.clone());
    let mut factored: BTreeMap<String, Vec<GroupFactors>> = BTreeMap::new();
    let mut stats = stats0;
    for (bi, &(bstart, blen)) in blocks.iter().enumerate() {
        if bi > 0 {
            // recalibrate with the compressed prefix reconstructed dense
            let current = model.to_dense();
            stats = recalib(&current)?;
        }
        for typ in COMPRESSIBLE {
            let (d1, d2) = cfg.matrix_dims(typ);
            let n_t = group_size(&cfg, typ, opts);
            let ks = &plan[typ];
            // groups of this type that start inside this block
            for (gi, (gstart, glen)) in layer_groups(cfg.layers, n_t).into_iter().enumerate() {
                if gstart < bstart || gstart >= bstart + blen {
                    continue;
                }
                let k = ks[gi];
                if k * (d1 + glen * d2) >= glen * d1 * d2 {
                    continue; // not worth factoring at this rank
                }
                let gs = super::methods::group_svd(weights, &stats, typ, gstart, glen, opts);
                factored
                    .entry(typ.to_string())
                    .or_default()
                    .push(gs.factors(k, d2));
            }
        }
        // update the model after each block so the next recalibration sees it
        for (typ, gfs) in &factored {
            model.reps.insert(typ.clone(), TypeRep::Factored(gfs.clone()));
        }
    }
    Ok((model, plan))
}
