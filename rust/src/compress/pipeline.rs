//! End-to-end compression pipeline, including the sequential compensation
//! the paper enables at ratios ≥ 40% ("we adaptively update the downstream
//! layer weights using the deviated inputs", §4.1).
//!
//! Compensated flow: layer blocks are compressed front-to-back; before each
//! block, calibration re-runs with the *already-compressed* prefix, so
//! downstream whitening sees the deviated activations. Rank allocation is
//! decided once up front from the clean statistics (the deviation shifts
//! whitening, not the information-density ordering).
//!
//! Recalibration is a pluggable seam ([`compensated_with`]): the provider
//! receives the partially-compressed model itself. The reference path
//! ([`compress_model_reference`]) runs the instrumented pure-Rust forward
//! *on the factors directly* (`calib::run_reference_model` — no dense
//! reconstruction, no `Reconstruct` stage calls), so the whole pipeline
//! runs (and is tested) with no `artifacts/` directory; the PJRT provider
//! reconstructs dense weight literals internally because the AOT calib
//! artifact requires them.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::Result;

use super::methods::{all_type_svds, compress, group_size, group_svd, plan_ranks, RankPlan};
use super::{layer_groups, CompressOpts};
use crate::calib::{self, CalibOpts, CalibStats};
use crate::data::DataBundle;
use crate::model::lowrank::{CompressedModel, GroupFactors, TypeRep};
use crate::model::{Weights, COMPRESSIBLE};
use crate::runtime::Engine;
use crate::util::parallel::parallel_map;

/// Calibrate + compress in one call (PJRT calibration path).
pub fn compress_model(
    engine: &Engine,
    weights: &Weights,
    data: &DataBundle,
    copts: &CalibOpts,
    opts: &CompressOpts,
) -> Result<(CompressedModel, RankPlan)> {
    let stats = calib::run(engine, weights, data, copts)?;
    compress_with_stats(engine, weights, data, stats, copts, opts)
}

/// Calibrate + compress entirely in pure Rust (no artifacts, no PJRT):
/// statistics come from the instrumented reference forward, and the
/// compensated path recalibrates the same way.
pub fn compress_model_reference(
    weights: &Weights,
    data: &DataBundle,
    copts: &CalibOpts,
    opts: &CompressOpts,
) -> Result<(CompressedModel, RankPlan)> {
    let stats = calib::run_reference(weights, data, copts)?;
    if !opts.compensate {
        return compress(weights, &stats, opts);
    }
    compensated_with(weights, stats, opts, |m| calib::run_reference_model(m, data, copts))
}

/// Compress given pre-computed statistics; dispatches on compensation.
pub fn compress_with_stats(
    engine: &Engine,
    weights: &Weights,
    data: &DataBundle,
    stats: CalibStats,
    copts: &CalibOpts,
    opts: &CompressOpts,
) -> Result<(CompressedModel, RankPlan)> {
    if !opts.compensate {
        return compress(weights, &stats, opts);
    }
    // the AOT calib artifact takes dense weight literals, so the PJRT
    // provider reconstructs; the reference provider never does
    compensated_with(weights, stats, opts, |m| {
        calib::run(engine, &m.to_dense(), data, copts)
    })
}

/// The §4.1 sequential-compensation loop over a pluggable recalibration
/// provider: `recalib` is invoked with the partially-compressed model
/// before each block after the first.
pub fn compensated_with(
    weights: &Weights,
    stats0: CalibStats,
    opts: &CompressOpts,
    mut recalib: impl FnMut(&CompressedModel) -> Result<CalibStats>,
) -> Result<(CompressedModel, RankPlan)> {
    opts.validate()?;
    let cfg = weights.config;
    // 1. allocation from clean statistics (one flat parallel SVD sweep)
    let svds = all_type_svds(weights, &stats0, opts);
    let plan = plan_ranks(&cfg, &svds, opts);

    // Skip rule aligned with `compress()`: a type whose *total* planned
    // factorization would not shrink it stays dense outright (rather than
    // only skipping the individual groups that hit break-even). Per-group
    // break-even holes can still occur below; `compressible_param_count`
    // charges those uncovered layers as dense.
    let mut keep_dense: BTreeSet<&'static str> = BTreeSet::new();
    for typ in COMPRESSIBLE {
        let (d1, d2) = cfg.matrix_dims(typ);
        let factored_params: usize = svds[typ]
            .iter()
            .zip(&plan[typ])
            .map(|(g, &k)| k * (d1 + g.n * d2))
            .sum();
        if factored_params >= cfg.layers * d1 * d2 {
            keep_dense.insert(typ);
        }
    }

    // Block 0 sees stats identical to planning, so its group SVDs are
    // reused verbatim (group_svd is deterministic — recomputing would give
    // the same bits). Invalidated at the first recalibration.
    let mut svds0 = Some(svds);

    // 2. block-by-block compression with recalibration. Block granularity is
    //    the grouping stride (max over types so group boundaries align).
    let stride = COMPRESSIBLE
        .iter()
        .map(|t| group_size(&cfg, t, opts))
        .max()
        .unwrap_or(1);
    let blocks = layer_groups(cfg.layers, stride);

    let mut model = CompressedModel::dense_passthrough(weights.clone());
    let mut factored: BTreeMap<String, Vec<GroupFactors>> = BTreeMap::new();
    let mut stats = stats0;
    for (bi, &(bstart, blen)) in blocks.iter().enumerate() {
        if bi > 0 {
            // recalibrate with the compressed prefix (the provider decides
            // whether it needs dense weights; the reference one doesn't)
            stats = recalib(&model)?;
            svds0 = None; // deviated stats: planning SVDs no longer valid
        }
        // collect this block's group work items: (typ, gi, gstart, glen, k, d2)
        let mut items: Vec<(&'static str, usize, usize, usize, usize, usize)> = Vec::new();
        for typ in COMPRESSIBLE {
            if keep_dense.contains(typ) {
                continue;
            }
            let (d1, d2) = cfg.matrix_dims(typ);
            let n_t = group_size(&cfg, typ, opts);
            let ks = &plan[typ];
            // groups of this type that start inside this block
            for (gi, (gstart, glen)) in layer_groups(cfg.layers, n_t).into_iter().enumerate() {
                if gstart < bstart || gstart >= bstart + blen {
                    continue;
                }
                let k = ks[gi];
                if k * (d1 + glen * d2) >= glen * d1 * d2 {
                    continue; // not worth factoring at this rank
                }
                items.push((typ, gi, gstart, glen, k, d2));
            }
        }
        // factor the block's groups in one parallel sweep; index-ordered
        // collection keeps the group order (hence the output) identical to
        // the sequential loop
        let stats_ref = &stats;
        let svds_ref = svds0.as_ref();
        let done = parallel_map(items, |(typ, gi, gstart, glen, k, d2)| {
            let gf = match svds_ref {
                Some(s) => s[typ][gi].factors(k, d2),
                None => group_svd(weights, stats_ref, typ, gstart, glen, opts).factors(k, d2),
            };
            (typ, gf)
        });
        for (typ, gf) in done {
            factored.entry(typ.to_string()).or_default().push(gf);
        }
        // update the model after each block so the next recalibration sees it
        for (typ, gfs) in &factored {
            model.reps.insert(typ.clone(), TypeRep::Factored(gfs.clone()));
        }
    }
    Ok((model, plan))
}
