//! The compression framework: the paper's contribution (D-Rank) plus the
//! five baselines it compares against, over shared machinery.
//!
//! | method          | scaling          | grouping | ranks                   |
//! |-----------------|------------------|----------|-------------------------|
//! | `svd`           | none             | n=1      | uniform                 |
//! | `fwsvd`         | Fisher rows      | n=1      | uniform                 |
//! | `asvd`          | diag (E|x|)^α    | n=1      | uniform                 |
//! | `svdllm`        | Cholesky whiten  | n=1      | uniform                 |
//! | `basis_sharing` | Cholesky whiten  | n        | uniform per group       |
//! | `drank`         | Cholesky whiten  | n (1 on GQA) | effective-rank Lagrange + β-rebalance |

pub mod alloc;
pub mod methods;
pub mod pipeline;
pub mod whiten;

use anyhow::{bail, Result};

/// Compression method selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    PlainSvd,
    Fwsvd,
    Asvd,
    SvdLlm,
    BasisSharing,
    DRank,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "svd" => Method::PlainSvd,
            "fwsvd" => Method::Fwsvd,
            "asvd" => Method::Asvd,
            "svdllm" | "svd-llm" => Method::SvdLlm,
            "basis" | "basis_sharing" => Method::BasisSharing,
            "drank" | "d-rank" => Method::DRank,
            _ => bail!("unknown method {s}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Method::PlainSvd => "SVD",
            Method::Fwsvd => "FWSVD",
            Method::Asvd => "ASVD",
            Method::SvdLlm => "SVD-LLM",
            Method::BasisSharing => "Basis Sharing",
            Method::DRank => "D-Rank",
        }
    }

    /// Does the method whiten with the Cholesky factor of the input Gram?
    pub fn whitens(self) -> bool {
        matches!(self, Method::SvdLlm | Method::BasisSharing | Method::DRank)
    }

    /// Does the method group layers for basis sharing?
    pub fn groups(self) -> bool {
        matches!(self, Method::BasisSharing | Method::DRank)
    }
}

/// Options for one compression run.
#[derive(Clone, Debug)]
pub struct CompressOpts {
    pub method: Method,
    /// target compression ratio θ over the compressible parameters
    pub ratio: f64,
    /// layers per group for grouping methods (the paper's n)
    pub group_layers: usize,
    /// β-rebalance fraction Q,K → V (D-Rank only)
    pub beta: f64,
    /// ASVD exponent α
    pub asvd_alpha: f64,
    /// honor the §3.4 GQA policy (force n=1 on GQA models) — D-Rank only
    pub gqa_policy: bool,
    /// sequential compensation: recalibrate with the compressed prefix
    /// before each layer block (the paper enables this at ratios >= 40%)
    pub compensate: bool,
}

impl Default for CompressOpts {
    fn default() -> Self {
        Self {
            method: Method::DRank,
            ratio: 0.2,
            group_layers: 2,
            beta: 0.3,
            asvd_alpha: 0.5,
            gqa_policy: true,
            compensate: false,
        }
    }
}

/// A rejected compression option: which flag and why. Typed (rather than a
/// bare `anyhow!`) so sweep drivers can catch it per-point instead of
/// aborting — and so `--beta 1.0` fails cleanly at parse time instead of
/// tripping the `beta_rebalance` assertion mid-run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OptsError {
    pub flag: &'static str,
    pub message: String,
}

impl std::fmt::Display for OptsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid --{}: {}", self.flag, self.message)
    }
}

impl std::error::Error for OptsError {}

impl CompressOpts {
    /// Validate ranges before any compute. β must lie in [0, 1): β is the
    /// *fraction* of Q/K rank budget moved to V, and `beta_rebalance`
    /// asserts the same half-open interval.
    pub fn validate(&self) -> Result<(), OptsError> {
        if !self.ratio.is_finite() || !(0.0..1.0).contains(&self.ratio) {
            return Err(OptsError {
                flag: "ratio",
                message: format!("{} not in [0, 1)", self.ratio),
            });
        }
        if !self.beta.is_finite() || !(0.0..1.0).contains(&self.beta) {
            return Err(OptsError {
                flag: "beta",
                message: format!("{} not in [0, 1) — β=1 would zero Q/K entirely", self.beta),
            });
        }
        if self.group_layers < 1 {
            return Err(OptsError {
                flag: "group-layers",
                message: "must be >= 1".to_string(),
            });
        }
        if !self.asvd_alpha.is_finite() || self.asvd_alpha < 0.0 {
            return Err(OptsError {
                flag: "asvd-alpha",
                message: format!("{} must be finite and >= 0", self.asvd_alpha),
            });
        }
        Ok(())
    }
}

/// Consecutive-layer grouping: L layers in chunks of n (tail may be short).
pub fn layer_groups(layers: usize, n: usize) -> Vec<(usize, usize)> {
    assert!(n >= 1);
    let mut out = Vec::new();
    let mut start = 0;
    while start < layers {
        let len = n.min(layers - start);
        out.push((start, len));
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("svd", Method::PlainSvd),
            ("fwsvd", Method::Fwsvd),
            ("asvd", Method::Asvd),
            ("svdllm", Method::SvdLlm),
            ("basis_sharing", Method::BasisSharing),
            ("drank", Method::DRank),
        ] {
            assert_eq!(Method::parse(s).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn validate_rejects_out_of_range_opts() {
        let ok = CompressOpts::default();
        assert!(ok.validate().is_ok());

        let mut beta_top = CompressOpts::default();
        beta_top.beta = 1.0; // top of a β sweep — must error, not panic
        let err = beta_top.validate().unwrap_err();
        assert_eq!(err.flag, "beta");
        assert!(err.to_string().contains("--beta"));

        let mut beta_neg = CompressOpts::default();
        beta_neg.beta = -0.1;
        assert!(beta_neg.validate().is_err());

        let mut bad_ratio = CompressOpts::default();
        bad_ratio.ratio = 1.0;
        assert_eq!(bad_ratio.validate().unwrap_err().flag, "ratio");

        let mut bad_group = CompressOpts::default();
        bad_group.group_layers = 0;
        assert_eq!(bad_group.validate().unwrap_err().flag, "group-layers");

        let mut bad_alpha = CompressOpts::default();
        bad_alpha.asvd_alpha = f64::NAN;
        assert_eq!(bad_alpha.validate().unwrap_err().flag, "asvd-alpha");
    }

    #[test]
    fn groups_cover_all_layers() {
        assert_eq!(layer_groups(6, 2), vec![(0, 2), (2, 2), (4, 2)]);
        assert_eq!(layer_groups(6, 4), vec![(0, 4), (4, 2)]);
        assert_eq!(layer_groups(6, 1).len(), 6);
        assert_eq!(layer_groups(6, 6), vec![(0, 6)]);
        let total: usize = layer_groups(7, 3).iter().map(|g| g.1).sum();
        assert_eq!(total, 7);
    }
}
