//! Evaluation harness: perplexity + zero-shot multiple-choice accuracy.
//!
//! PPL runs token streams through the AOT `dense_nll` artifact (compressed
//! models are reconstructed W ≈ B·C first — numerically equivalent to the
//! factored graph, see the integration tests), or — artifact-free — through
//! the pure-Rust forward: [`ppl_reference`] scores a compressed model on
//! its factors directly (`model::fwd::nll_model`), never materializing
//! dense weights. Zero-shot scoring follows LM-Evaluation-Harness: each
//! option is scored by length-normalized log-likelihood as a continuation
//! of the prompt, highest wins.

pub mod tasks;

use anyhow::Result;

use crate::data::Batcher;
use crate::model::lowrank::CompressedModel;
use crate::model::Weights;
use crate::runtime::{lit_i32, Engine};

/// Perplexity of a dense model over a token stream.
/// `max_batches` bounds cost; the stream is consumed sequentially.
pub fn ppl_dense(
    engine: &Engine,
    weights: &Weights,
    stream: &[u32],
    max_batches: usize,
) -> Result<f64> {
    let cfg = weights.config;
    engine.check_config(&cfg)?;
    let batches = Batcher::eval_batches(stream, cfg.batch, cfg.seq, max_batches);
    anyhow::ensure!(!batches.is_empty(), "stream too short for evaluation");
    let wlits = engine.weight_literals(weights)?; // upload-once weight cache
    let mut total = 0.0f64;
    let mut count = 0usize;
    for batch in &batches {
        let tok = lit_i32(batch, &[cfg.batch, cfg.seq])?;
        let mut inputs: Vec<&xla::Literal> = wlits.iter().collect();
        inputs.push(&tok);
        let outs = engine.exec(cfg.name, "dense_nll", &inputs)?;
        let nll = outs[0].to_vec::<f32>()?;
        total += nll.iter().map(|&x| x as f64).sum::<f64>();
        count += nll.len();
    }
    Ok((total / count as f64).exp())
}

/// Perplexity of a compressed model (dense reconstruction path).
///
/// This PJRT path genuinely needs dense weights — the AOT `dense_nll`
/// artifact takes weight literals, not factors. For artifact-free factored
/// evaluation use [`ppl_reference`].
pub fn ppl_compressed(
    engine: &Engine,
    model: &CompressedModel,
    stream: &[u32],
    max_batches: usize,
) -> Result<f64> {
    let dense = model.to_dense();
    ppl_dense(engine, &dense, stream, max_batches)
}

/// Perplexity of a compressed model through the pure-Rust forward,
/// consuming factored weights directly (no PJRT, no `Reconstruct` calls).
/// Batches run sequentially; the forward itself row-band-parallelizes on
/// the shared pool, so the result is bit-identical for any thread count.
pub fn ppl_reference(
    model: &CompressedModel,
    stream: &[u32],
    max_batches: usize,
) -> Result<f64> {
    let cfg = model.config();
    let batches = Batcher::eval_batches(stream, cfg.batch, cfg.seq, max_batches);
    anyhow::ensure!(!batches.is_empty(), "stream too short for evaluation");
    let mut total = 0.0f64;
    let mut count = 0usize;
    for batch in &batches {
        let nll = crate::model::fwd::nll_model(model, batch, cfg.batch, cfg.seq);
        total += nll.iter().map(|&x| x as f64).sum::<f64>();
        count += nll.len();
    }
    Ok((total / count as f64).exp())
}

/// Sum of log-likelihoods of `cont` tokens following `prompt` tokens,
/// computed from a per-token NLL row of a padded sequence.
pub(crate) fn continuation_logprob(nll_row: &[f32], prompt_len: usize, cont_len: usize) -> f64 {
    // nll_row[t] is the NLL of predicting token t+1; continuation tokens sit
    // at sequence positions prompt_len .. prompt_len+cont_len-1, i.e. they
    // are predicted at nll indices prompt_len-1 .. prompt_len+cont_len-2.
    let start = prompt_len - 1;
    -(nll_row[start..start + cont_len].iter().map(|&x| x as f64).sum::<f64>())
}

/// Batched NLL evaluator with padding for variable-length sequences.
/// Weight literals are built once and reused across every batch.
pub struct NllScorer<'a> {
    engine: &'a Engine,
    config: crate::model::ModelConfig,
    wlits: Vec<xla::Literal>,
}

impl<'a> NllScorer<'a> {
    pub fn new(engine: &'a Engine, weights: Weights) -> Result<Self> {
        engine.check_config(&weights.config)?;
        let wlits = engine.weight_literals(&weights)?;
        Ok(Self { engine, config: weights.config, wlits })
    }

    /// Per-token NLL rows for a set of sequences (each <= cfg.seq long).
    /// Sequences are padded with token 0 and packed into fixed batches.
    pub fn nll_rows(&self, seqs: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let cfg = self.config;
        let (bsz, s) = (cfg.batch, cfg.seq);
        let mut rows = Vec::with_capacity(seqs.len());
        for chunk in seqs.chunks(bsz) {
            let mut batch = vec![0i32; bsz * s];
            for (r, seq) in chunk.iter().enumerate() {
                anyhow::ensure!(seq.len() <= s, "sequence longer than model seq");
                for (i, &t) in seq.iter().enumerate() {
                    batch[r * s + i] = t as i32;
                }
            }
            let tok = lit_i32(&batch, &[bsz, s])?;
            let mut inputs: Vec<&xla::Literal> = self.wlits.iter().collect();
            inputs.push(&tok);
            let outs = self.engine.exec(cfg.name, "dense_nll", &inputs)?;
            let nll = outs[0].to_vec::<f32>()?;
            for r in 0..chunk.len() {
                rows.push(nll[r * (s - 1)..(r + 1) * (s - 1)].to_vec());
            }
        }
        Ok(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn continuation_logprob_indexing() {
        // prompt of 3 tokens, continuation of 2: indices 2 and 3
        let nll = [10.0, 20.0, 1.0, 2.0, 40.0];
        let lp = continuation_logprob(&nll, 3, 2);
        assert!((lp - (-3.0)).abs() < 1e-9);
        // whole-row continuation after a single-token prompt
        let lp2 = continuation_logprob(&nll, 1, 5);
        assert!((lp2 + 73.0).abs() < 1e-9);
    }
}
