//! Zero-shot task scoring (lm-eval style).
//!
//! For each item: tokenize prompt and each option separately (BPE merges
//! never cross the prompt/option boundary — options start with a space and
//! the tokenizer is word-bounded), score every (prompt ‖ option) sequence,
//! and pick the option with the highest length-normalized continuation
//! log-likelihood (acc_norm in lm-eval terms).

use anyhow::Result;

use super::{continuation_logprob, NllScorer};
use crate::data::synlang::Lexicon;
use crate::data::tasks::{Suite, ALL_SUITES};
use crate::model::Weights;
use crate::runtime::Engine;
use crate::tokenizer::Tokenizer;

/// Accuracy of one suite.
pub fn run_suite(
    engine: &Engine,
    weights: &Weights,
    tok: &Tokenizer,
    lex: &Lexicon,
    suite: Suite,
    n_items: usize,
    seed: u64,
) -> Result<f64> {
    let scorer = NllScorer::new(engine, weights.clone())?;
    let items = suite.items(lex, n_items, seed);
    let max_len = weights.config.seq;

    // flatten all (prompt||option) sequences to score in packed batches
    let mut seqs: Vec<Vec<u32>> = Vec::new();
    let mut meta: Vec<(usize, usize, usize)> = Vec::new(); // (item, prompt_len, cont_len)
    for item in &items {
        let p = tok.encode(&item.prompt);
        for opt in &item.options {
            let c = tok.encode(opt);
            let mut s = p.clone();
            s.extend(&c);
            anyhow::ensure!(!p.is_empty() && !c.is_empty(), "empty encoding");
            anyhow::ensure!(s.len() <= max_len, "item longer than model seq");
            meta.push((0, p.len(), c.len()));
            seqs.push(s);
        }
    }
    let rows = scorer.nll_rows(&seqs)?;

    // pick argmax per item
    let mut correct = 0usize;
    let mut ri = 0usize;
    for item in &items {
        let mut best = (f64::MIN, 0usize);
        for (oi, _) in item.options.iter().enumerate() {
            let (_, plen, clen) = meta[ri];
            let lp = continuation_logprob(&rows[ri], plen, clen) / clen as f64;
            if lp > best.0 {
                best = (lp, oi);
            }
            ri += 1;
        }
        if best.1 == item.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len() as f64)
}

/// Accuracy over all seven suites + their mean (the paper's Average*).
pub fn run_all_suites(
    engine: &Engine,
    weights: &Weights,
    tok: &Tokenizer,
    lex: &Lexicon,
    n_items: usize,
    seed: u64,
) -> Result<(Vec<(Suite, f64)>, f64)> {
    let mut out = Vec::new();
    for suite in ALL_SUITES {
        let acc = run_suite(engine, weights, tok, lex, suite, n_items, seed)?;
        out.push((suite, acc));
    }
    let avg = out.iter().map(|(_, a)| a).sum::<f64>() / out.len() as f64;
    Ok((out, avg))
}

/// Chance-level accuracy of a suite (for sanity checks and reporting).
pub fn chance(suite: Suite) -> f64 {
    1.0 / suite.n_options() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chance_levels() {
        assert_eq!(chance(Suite::Winogrande), 0.5);
        assert_eq!(chance(Suite::Mathqa), 0.25);
    }

    #[test]
    fn items_fit_tiny_seq() {
        // every generated item must tokenize within the smallest model's seq
        let lex = Lexicon::new();
        let corpus = crate::data::synlang::Generator::new(
            &lex,
            crate::data::synlang::Domain::Wiki2s,
            1,
        )
        .corpus(200_000);
        let tok = Tokenizer::train(&corpus, 256);
        for suite in ALL_SUITES {
            for item in suite.items(&lex, 40, 3) {
                let p = tok.encode(&item.prompt);
                assert!(!p.is_empty());
                for opt in &item.options {
                    let c = tok.encode(opt);
                    assert!(!c.is_empty(), "{item:?}");
                    assert!(p.len() + c.len() <= 64, "{item:?}");
                }
            }
        }
    }

    #[test]
    fn boundary_is_word_aligned() {
        // encode(prompt) + encode(option) == encode(prompt + option),
        // guaranteeing continuation_logprob indexes real token boundaries
        let lex = Lexicon::new();
        let corpus = crate::data::synlang::Generator::new(
            &lex,
            crate::data::synlang::Domain::Wiki2s,
            2,
        )
        .corpus(100_000);
        let tok = Tokenizer::train(&corpus, 256);
        for item in Suite::Openbook.items(&lex, 20, 5) {
            let full = tok.encode(&format!("{}{}", item.prompt, item.options[0]));
            let mut parts = tok.encode(&item.prompt);
            parts.extend(tok.encode(&item.options[0]));
            assert_eq!(full, parts, "{item:?}");
        }
    }
}
