//! Shared utilities: PRNG, JSON, CLI argument parsing, timing, threading.

pub mod cli;
pub mod json;
pub mod parallel;
pub mod profile;
pub mod rng;

use std::time::Instant;

/// Simple scope timer for the perf logs.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
