//! Deterministic PRNG (splitmix64 + xoshiro256**) — no external crates are
//! available offline, and determinism across runs matters for the paper's
//! seed-robustness experiment (Fig. 5), so we own the generator.

/// xoshiro256** seeded via splitmix64. Passes BigCrush per the reference
/// implementation; more than adequate for data generation and init.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        Self {
            s: [
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
            ],
        }
    }

    /// Derive an independent stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized nonnegative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..20000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..30000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let p2 = counts[2] as f64 / 30000.0;
        assert!((p2 - 0.7).abs() < 0.03, "p2 {p2}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(13);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
