//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Present-and-parseable value, else `None` (for truly optional knobs
    /// like `--deadline-ms` where absence means "disabled").
    pub fn opt_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// `--key <ms>` as a `Duration` (serving knobs: batch windows,
    /// deadlines).
    pub fn duration_ms_or(&self, key: &str, default_ms: u64) -> std::time::Duration {
        std::time::Duration::from_millis(self.u64_or(key, default_ms))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// `--threads N` with the process default (DRANK_THREADS env /
    /// available parallelism) as fallback; clamped to ≥ 1.
    pub fn threads_or_default(&self) -> usize {
        self.usize_or("threads", crate::util::parallel::default_threads()).max(1)
    }

    /// Comma-separated list value.
    pub fn list_or(&self, key: &str, default: &str) -> Vec<String> {
        self.str_or(key, default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("train --model m --steps=400 --verbose --lr 0.003 out.bin");
        assert_eq!(a.positional, vec!["train", "out.bin"]);
        assert_eq!(a.get("model"), Some("m"));
        assert_eq!(a.usize_or("steps", 0), 400);
        assert!(a.has("verbose"));
        assert_eq!(a.f64_or("lr", 0.0), 0.003);
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn list_values() {
        let a = parse("--ratios 0.2,0.3,0.4");
        assert_eq!(a.list_or("ratios", ""), vec!["0.2", "0.3", "0.4"]);
        assert_eq!(a.list_or("other", "x,y"), vec!["x", "y"]);
    }

    #[test]
    fn optional_and_duration_values() {
        let a = parse("--workers 4 --batch-window-ms 7 --deadline-ms 250");
        assert_eq!(a.usize_or("workers", 1), 4);
        assert_eq!(a.duration_ms_or("batch-window-ms", 2).as_millis(), 7);
        assert_eq!(a.duration_ms_or("missing-ms", 2).as_millis(), 2);
        assert_eq!(a.opt_usize("deadline-ms"), Some(250));
        assert_eq!(a.opt_usize("absent"), None);
    }

    #[test]
    fn threads_flag() {
        let a = parse("--threads 4");
        assert_eq!(a.threads_or_default(), 4);
        let b = parse("--threads 0");
        assert_eq!(b.threads_or_default(), 1); // clamped up
        let c = parse("");
        assert!(c.threads_or_default() >= 1);
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = parse("--flag --key value");
        assert!(a.has("flag"));
        assert_eq!(a.get("key"), Some("value"));
    }
}
