//! Minimal JSON parser/emitter (serde is unavailable offline).
//!
//! Covers the full JSON grammar minus exotic escapes; used for the artifact
//! manifest, checkpoints metadata, experiment reports, and the coordinator's
//! wire protocol.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- construction helpers --------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn arr_num(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize (stable key order via BTreeMap).
    pub fn emit(&self) -> String {
        let mut s = String::new();
        self.emit_into(&mut s);
        s
    }

    fn emit_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => emit_string(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.emit_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_string(k, out);
                    out.push(':');
                    v.emit_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn emit_string(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\"y\n"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("e").unwrap().as_str(), Some("x\"y\n"));
        // emit -> parse -> equal
        let re = Json::parse(&v.emit()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::num(42.0).emit(), "42");
        assert_eq!(Json::num(0.5).emit(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn manifest_like_shape() {
        let src = r#"{"artifacts":[{"file":"m_dense_nll.hlo.txt","inputs":[{"name":"embed","shape":[512,192],"dtype":"f32"}]}]}"#;
        let v = Json::parse(src).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        let ins = a.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].get("shape").unwrap().as_arr().unwrap()[1].as_usize(), Some(192));
    }
}
