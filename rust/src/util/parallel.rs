//! Dependency-free data-parallel primitives (no external crates — this
//! repo vendors offline, so no rayon).
//!
//! Two shapes cover every hot path in the compression engine:
//!
//!  - [`parallel_map`]: a scoped thread pool (`std::thread::scope` + one
//!    atomic work index) over an owned work list, with **index-ordered
//!    collection** — results come back in item order no matter which
//!    thread ran which item.
//!  - [`parallel_row_bands`]: split the rows of a row-major buffer into
//!    one contiguous band per thread and hand each thread a disjoint
//!    `&mut` band (GEMM / Gram row parallelism).
//!  - [`parallel_pair_rows`]: hand each worker one *disjoint* (p,q) row
//!    pair of a row-major buffer as two `&mut` row slices (the blocked
//!    Jacobi row phase: each rotation of a tournament round owns exactly
//!    two rows, and rounds are built so no two rotations share an index).
//!
//! **Bit-determinism contract:** every function here guarantees output
//! bit-identical to a single-threaded run, for any thread count. That
//! holds because the unit of work (one SVD, one output row, one calib
//! batch) is computed by exactly the same instruction sequence regardless
//! of the split, and no floating-point reduction ever crosses a work-unit
//! boundary. The determinism test suite (`rust/tests/determinism.rs`)
//! enforces this across all six compression methods and both pipelines.
//!
//! The pool size is a process-wide setting: `--threads N` on the CLI (or
//! the `DRANK_THREADS` env var for benches/tests) feeds [`set_threads`];
//! unset, it defaults to `std::thread::available_parallelism`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Configured pool size; 0 means "not set, use the default".
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// The default pool size: `DRANK_THREADS` if set and valid, else the
/// machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DRANK_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Set the process-wide pool size. 0 resets to the default
/// (`DRANK_THREADS` / available parallelism).
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// The pool size parallel helpers will use right now.
pub fn threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => default_threads(),
        n => n,
    }
}

/// Map `f` over `items` on up to [`threads`] worker threads.
///
/// Work is claimed through a single atomic index (dynamic load balancing —
/// SVD costs vary a lot between groups), and results are written back into
/// the slot of their item index, so the returned `Vec` is in item order
/// and bit-identical to `items.into_iter().map(f).collect()`.
///
/// A panic in `f` propagates to the caller (via `std::thread::scope`).
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let nthreads = threads().min(n);
    if nthreads <= 1 {
        return items.into_iter().map(f).collect();
    }
    let work: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let done: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..nthreads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i].lock().unwrap().take().expect("work item claimed twice");
                let out = f(item);
                *done[i].lock().unwrap() = Some(out);
            });
        }
    });
    done.into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker dropped a result"))
        .collect()
}

/// Run `f(first_row, band)` over contiguous whole-row bands of a row-major
/// `rows`×`cols` buffer, one band per thread.
///
/// Each band is a disjoint `&mut [T]` (via `chunks_mut`), so this is safe
/// shared-nothing parallelism. Because `f` must compute each row by the
/// same instruction sequence wherever the band boundaries fall, the output
/// is bit-identical for any thread count.
pub fn parallel_row_bands<T, F>(data: &mut [T], rows: usize, cols: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(data.len(), rows * cols, "row-band shape mismatch");
    if rows == 0 || cols == 0 {
        return;
    }
    let nthreads = threads().min(rows);
    if nthreads <= 1 {
        f(0, data);
        return;
    }
    let band = rows.div_ceil(nthreads);
    std::thread::scope(|s| {
        for (bi, chunk) in data.chunks_mut(band * cols).enumerate() {
            let f = &f;
            s.spawn(move || f(bi * band, chunk));
        }
    });
}

/// Run `f(pair_index, row_p, row_q)` once per (p, q) entry of `pairs`,
/// handing it mutable access to rows p and q of a row-major `rows`×`cols`
/// buffer. Pairs MUST be disjoint (no row index appears twice across the
/// whole list) — checked up front — which is what makes the unsafe row
/// split below sound and the scheduling embarrassingly parallel.
///
/// Each pair's computation reads and writes only its own two rows, so the
/// result is bit-identical for any thread count (pairs are claimed through
/// the same atomic work index as [`parallel_map`]; which thread runs a
/// pair cannot influence any element's value).
pub fn parallel_pair_rows<T, F>(
    data: &mut [T],
    rows: usize,
    cols: usize,
    pairs: &[(usize, usize)],
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T], &mut [T]) + Sync,
{
    assert_eq!(data.len(), rows * cols, "pair-row shape mismatch");
    let mut seen = vec![false; rows];
    for &(p, q) in pairs {
        assert!(p < rows && q < rows && p != q, "bad row pair ({p},{q})");
        assert!(!seen[p] && !seen[q], "row repeated across pairs ({p},{q})");
        seen[p] = true;
        seen[q] = true;
    }
    if cols == 0 || pairs.is_empty() {
        return;
    }
    let base = data.as_mut_ptr() as usize;
    let run = |i: usize| {
        let (p, q) = pairs[i];
        // SAFETY: pairs are in range and disjoint (asserted above), so the
        // two slices alias neither each other nor any other pair's rows,
        // and every access stays inside `data`.
        let rp = unsafe {
            std::slice::from_raw_parts_mut((base as *mut T).add(p * cols), cols)
        };
        let rq = unsafe {
            std::slice::from_raw_parts_mut((base as *mut T).add(q * cols), cols)
        };
        f(i, rp, rq);
    };
    let nthreads = threads().min(pairs.len());
    if nthreads <= 1 {
        for i in 0..pairs.len() {
            run(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..nthreads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= pairs.len() {
                    break;
                }
                run(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_item_order() {
        let items: Vec<usize> = (0..137).collect();
        let got = parallel_map(items.clone(), |x| x * 3 + 1);
        let want: Vec<usize> = items.iter().map(|x| x * 3 + 1).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn map_handles_empty_and_single() {
        let empty: Vec<usize> = Vec::new();
        assert!(parallel_map(empty, |x: usize| x).is_empty());
        assert_eq!(parallel_map(vec![9usize], |x| x + 1), vec![10]);
    }

    #[test]
    fn row_bands_cover_every_row_once() {
        let (rows, cols) = (23, 7);
        let mut data = vec![0u32; rows * cols];
        parallel_row_bands(&mut data, rows, cols, |row0, band| {
            let brows = band.len() / cols;
            for i in 0..brows {
                for j in 0..cols {
                    band[i * cols + j] += ((row0 + i) * cols + j) as u32 + 1;
                }
            }
        });
        for (idx, v) in data.iter().enumerate() {
            assert_eq!(*v, idx as u32 + 1, "row element touched != once");
        }
    }

    #[test]
    fn row_bands_degenerate_shapes() {
        let mut none: Vec<f64> = Vec::new();
        parallel_row_bands(&mut none, 0, 5, |_, _| panic!("no rows, no calls"));
        parallel_row_bands(&mut none, 5, 0, |_, _| panic!("no cols, no calls"));
        let mut one = vec![0.0f64; 4];
        parallel_row_bands(&mut one, 1, 4, |row0, band| {
            assert_eq!(row0, 0);
            for x in band.iter_mut() {
                *x = 2.0;
            }
        });
        assert!(one.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn pair_rows_touch_exactly_their_rows() {
        let (rows, cols) = (9, 5);
        let mut data = vec![0i64; rows * cols];
        // pairs cover rows {0,3,1,7,4,8}; rows 2, 5, 6 stay untouched
        let pairs = [(0usize, 3usize), (1, 7), (4, 8)];
        parallel_pair_rows(&mut data, rows, cols, &pairs, |i, rp, rq| {
            for x in rp.iter_mut() {
                *x += 100 * (i as i64 + 1) + 1;
            }
            for x in rq.iter_mut() {
                *x += 100 * (i as i64 + 1) + 2;
            }
        });
        for r in 0..rows {
            let want = match r {
                0 => 101,
                3 => 102,
                1 => 201,
                7 => 202,
                4 => 301,
                8 => 302,
                _ => 0,
            };
            for c in 0..cols {
                assert_eq!(data[r * cols + c], want, "row {r}");
            }
        }
    }

    #[test]
    fn pair_rows_can_swap_row_contents() {
        // reading one row while writing the other is the blocked-Jacobi
        // access pattern; a swap exercises both directions at once
        let (rows, cols) = (4, 3);
        let mut data: Vec<u32> = (0..(rows * cols) as u32).collect();
        let orig = data.clone();
        parallel_pair_rows(&mut data, rows, cols, &[(0, 2), (1, 3)], |_, rp, rq| {
            for j in 0..rp.len() {
                std::mem::swap(&mut rp[j], &mut rq[j]);
            }
        });
        for j in 0..cols {
            assert_eq!(data[j], orig[2 * cols + j]);
            assert_eq!(data[2 * cols + j], orig[j]);
            assert_eq!(data[cols + j], orig[3 * cols + j]);
            assert_eq!(data[3 * cols + j], orig[cols + j]);
        }
    }

    #[test]
    fn pair_rows_empty_inputs_are_no_ops() {
        let mut data = vec![1.0f64; 12];
        parallel_pair_rows(&mut data, 4, 3, &[], |_, _, _| panic!("no pairs"));
        assert!(data.iter().all(|&x| x == 1.0));
        let mut none: Vec<f64> = Vec::new();
        parallel_pair_rows(&mut none, 4, 0, &[(0, 1)], |_, _, _| {
            panic!("no cols, no calls")
        });
    }

    #[test]
    #[should_panic(expected = "row repeated across pairs")]
    fn pair_rows_reject_overlapping_pairs() {
        let mut data = vec![0u8; 12];
        parallel_pair_rows(&mut data, 4, 3, &[(0, 1), (1, 2)], |_, _, _| {});
    }

    #[test]
    fn thread_setting_roundtrip() {
        let before = threads();
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0); // reset to default
        assert!(threads() >= 1);
        let _ = before;
    }
}
