//! Per-stage timing for the compression engine and the serving forward.
//!
//! Twelve stages cover the hot path end to end: calibration forward passes,
//! Gram formation (calib Gram accumulation + the A·Aᵀ / AᵀA products inside
//! `svd`), whitening (Cholesky of the Gram), the Jacobi eigensolve — split
//! into its sweep loop (`eigen_sweep`, the blocked-parallel part) and the
//! final sort/permute (`eigen_sort`, sequential and cheap) so the profile
//! shows exactly which part of the old `eigen` stage parallelized —
//! truncation (factor extraction, including the unwhitening solve), dense
//! reconstruction, the two serving-forward GEMM stages: `fwd` (dense
//! y = x·W projections) and `fwd_lowrank` (factored y = (x·B)·C
//! projections), `attn` — the blocked streaming-softmax attention
//! kernel, the serving forward's non-GEMM hot loop — and the two
//! generation stages: `prefill` (the batched cache-writing pass over the
//! prompt) and `decode` (the single-token cached step, one call per
//! emitted token). The split lets the
//! coordinator tests assert that factored serving never reconstructs
//! (`reconstruct` calls stay flat while `fwd_lowrank` climbs), and the
//! `attn_tiny` bench row regression-gate the attention rewrite. Note the
//! generation stage names deliberately avoid the `fwd`/`eigen` prefixes so
//! `fwd_ms()`/`eigen_ms()` keep their historical meanings. Counters
//! are process-global atomics so they can be
//! bumped from worker threads without plumbing a handle through every call;
//! `cpu_ms` therefore sums time across threads (it can exceed wall time —
//! that's the point: wall/cpu shows how well a stage parallelizes).
//!
//! Usage: `profile::reset()` at the start of a run, do the work, then
//! `profile::snapshot(wall_ms)` to get a [`CompressProfile`] for rendering
//! or JSON emission (`drank compress` prints it and writes
//! `runs/reports/compress_profile_<model>.json`; `perf_hotpath` folds it
//! into `BENCH_perf_hotpath.json`).

use crate::util::json::Json;
use crate::util::parallel;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Calib = 0,
    Gram = 1,
    Whiten = 2,
    EigenSweep = 3,
    EigenSort = 4,
    Truncate = 5,
    Reconstruct = 6,
    Fwd = 7,
    FwdLowrank = 8,
    Attn = 9,
    Prefill = 10,
    Decode = 11,
}

pub const STAGE_NAMES: [&str; 12] = [
    "calib", "gram", "whiten", "eigen_sweep", "eigen_sort", "truncate", "reconstruct",
    "fwd", "fwd_lowrank", "attn", "prefill", "decode",
];

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static NANOS: [AtomicU64; 12] = [ZERO; 12];
static CALLS: [AtomicU64; 12] = [ZERO; 12];

/// Zero all stage counters (call before a profiled run).
pub fn reset() {
    for i in 0..STAGE_NAMES.len() {
        NANOS[i].store(0, Ordering::Relaxed);
        CALLS[i].store(0, Ordering::Relaxed);
    }
}

fn record(stage: Stage, nanos: u64) {
    NANOS[stage as usize].fetch_add(nanos, Ordering::Relaxed);
    CALLS[stage as usize].fetch_add(1, Ordering::Relaxed);
}

/// Current call count of a stage. Tests read deltas of this around a
/// region to assert which code path ran (e.g. "factored serving never
/// entered `Reconstruct`").
pub fn stage_calls(stage: Stage) -> u64 {
    CALLS[stage as usize].load(Ordering::Relaxed)
}

/// Time a closure under `stage`.
pub fn time<R>(stage: Stage, f: impl FnOnce() -> R) -> R {
    let t0 = Instant::now();
    let out = f();
    record(stage, t0.elapsed().as_nanos() as u64);
    out
}

/// Drop-guard timer for functions with early returns / `?`.
pub struct ScopedTimer {
    stage: Stage,
    start: Instant,
}

impl ScopedTimer {
    pub fn new(stage: Stage) -> Self {
        ScopedTimer { stage, start: Instant::now() }
    }
}

impl Drop for ScopedTimer {
    fn drop(&mut self) {
        record(self.stage, self.start.elapsed().as_nanos() as u64);
    }
}

#[derive(Clone, Debug)]
pub struct StageTiming {
    pub name: &'static str,
    pub cpu_ms: f64,
    pub calls: u64,
}

/// A snapshot of the per-stage counters for one compression run.
#[derive(Clone, Debug)]
pub struct CompressProfile {
    pub threads: usize,
    pub wall_ms: f64,
    pub stages: Vec<StageTiming>,
}

/// Read the counters into a [`CompressProfile`]. `wall_ms` is the caller's
/// end-to-end wall time for the profiled region.
pub fn snapshot(wall_ms: f64) -> CompressProfile {
    let stages = (0..STAGE_NAMES.len())
        .map(|i| StageTiming {
            name: STAGE_NAMES[i],
            cpu_ms: NANOS[i].load(Ordering::Relaxed) as f64 / 1e6,
            calls: CALLS[i].load(Ordering::Relaxed),
        })
        .collect();
    CompressProfile { threads: parallel::threads(), wall_ms, stages }
}

impl CompressProfile {
    /// Total eigensolver cpu-ms (sweep + sort) — the quantity the perf
    /// regression gate compares against its baseline.
    pub fn eigen_ms(&self) -> f64 {
        self.stages
            .iter()
            .filter(|s| s.name.starts_with("eigen"))
            .map(|s| s.cpu_ms)
            .sum()
    }

    /// Total serving-forward cpu-ms (dense `fwd` + `fwd_lowrank`) — gated
    /// by `perf_hotpath` the same way as [`CompressProfile::eigen_ms`].
    pub fn fwd_ms(&self) -> f64 {
        self.stages
            .iter()
            .filter(|s| s.name.starts_with("fwd"))
            .map(|s| s.cpu_ms)
            .sum()
    }

    /// Cpu-ms of one stage by name (0.0 for unknown names).
    pub fn stage_ms(&self, name: &str) -> f64 {
        self.stages.iter().find(|s| s.name == name).map_or(0.0, |s| s.cpu_ms)
    }

    /// Human-readable table for terminal output.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "stage profile ({} threads, {:.1} ms wall):",
            self.threads, self.wall_ms
        );
        let _ = writeln!(s, "  {:<12} {:>10} {:>8}", "stage", "cpu ms", "calls");
        for st in &self.stages {
            let _ = writeln!(s, "  {:<12} {:>10.2} {:>8}", st.name, st.cpu_ms, st.calls);
        }
        let cpu_total: f64 = self.stages.iter().map(|s| s.cpu_ms).sum();
        let _ = writeln!(s, "  {:<12} {:>10.2}", "total cpu", cpu_total);
        s
    }

    pub fn to_json(&self) -> Json {
        let stages = self
            .stages
            .iter()
            .map(|st| {
                Json::obj(vec![
                    ("name", Json::str(st.name)),
                    ("cpu_ms", Json::num(st.cpu_ms)),
                    ("calls", Json::num(st.calls as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("threads", Json::num(self.threads as f64)),
            ("wall_ms", Json::num(self.wall_ms)),
            ("stages", Json::Arr(stages)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    // The counters are process-global and other modules' tests (compress,
    // svd, to_dense) bump them concurrently, so: serialize the tests that
    // call reset() against each other, and assert only deltas / lower
    // bounds — never exact global values.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn counters_accumulate() {
        let _g = LOCK.lock().unwrap();
        let before = snapshot(0.0);
        time(Stage::Gram, || std::hint::black_box(1 + 1));
        {
            let _t = ScopedTimer::new(Stage::EigenSweep);
        }
        time(Stage::EigenSort, || std::hint::black_box(2 + 2));
        let after = snapshot(1.0);
        let calls = |p: &CompressProfile, name: &str| {
            p.stages.iter().find(|s| s.name == name).unwrap().calls
        };
        assert!(calls(&after, "gram") >= calls(&before, "gram") + 1);
        assert!(calls(&after, "eigen_sweep") >= calls(&before, "eigen_sweep") + 1);
        assert!(calls(&after, "eigen_sort") >= calls(&before, "eigen_sort") + 1);
        assert!(after.eigen_ms() >= before.eigen_ms());
        assert_eq!(after.wall_ms, 1.0);
    }

    #[test]
    fn json_shape_is_stable() {
        let _g = LOCK.lock().unwrap();
        time(Stage::Calib, || ());
        let j = snapshot(2.5).to_json();
        assert!(j.get("threads").and_then(|v| v.as_usize()).unwrap() >= 1);
        assert_eq!(j.get("wall_ms").and_then(|v| v.as_f64()), Some(2.5));
        let stages = j.get("stages").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(stages.len(), 12);
        assert_eq!(stages[0].get("name").and_then(|v| v.as_str()), Some("calib"));
        assert_eq!(stages[7].get("name").and_then(|v| v.as_str()), Some("fwd"));
        assert_eq!(stages[8].get("name").and_then(|v| v.as_str()), Some("fwd_lowrank"));
        assert_eq!(stages[9].get("name").and_then(|v| v.as_str()), Some("attn"));
        assert_eq!(stages[10].get("name").and_then(|v| v.as_str()), Some("prefill"));
        assert_eq!(stages[11].get("name").and_then(|v| v.as_str()), Some("decode"));
    }

    #[test]
    fn generation_stages_count_and_stay_out_of_fwd_ms() {
        let _g = LOCK.lock().unwrap();
        let before = snapshot(0.0);
        time(Stage::Prefill, || std::hint::black_box(1 + 1));
        time(Stage::Decode, || std::hint::black_box(2 + 2));
        let after = snapshot(0.0);
        let calls = |p: &CompressProfile, name: &str| {
            p.stages.iter().find(|s| s.name == name).unwrap().calls
        };
        assert!(calls(&after, "prefill") >= calls(&before, "prefill") + 1);
        assert!(calls(&after, "decode") >= calls(&before, "decode") + 1);
        // prefill/decode must not leak into the historical fwd/eigen sums.
        let only_gen = CompressProfile {
            threads: 1,
            wall_ms: 0.0,
            stages: vec![
                StageTiming { name: "prefill", cpu_ms: 3.0, calls: 1 },
                StageTiming { name: "decode", cpu_ms: 7.0, calls: 4 },
            ],
        };
        assert_eq!(only_gen.fwd_ms(), 0.0);
        assert_eq!(only_gen.eigen_ms(), 0.0);
        assert_eq!(only_gen.stage_ms("prefill"), 3.0);
        assert_eq!(only_gen.stage_ms("decode"), 7.0);
    }

    #[test]
    fn attn_stage_counts_and_is_not_a_fwd_stage() {
        let _g = LOCK.lock().unwrap();
        let before = snapshot(0.0);
        time(Stage::Attn, || std::hint::black_box(1 + 1));
        let after = snapshot(0.0);
        let calls = |p: &CompressProfile, name: &str| {
            p.stages.iter().find(|s| s.name == name).unwrap().calls
        };
        assert!(calls(&after, "attn") >= calls(&before, "attn") + 1);
        assert!(after.stage_ms("attn") >= before.stage_ms("attn"));
        // fwd_ms must keep its historical meaning (the "fwd*" GEMM stages):
        // a profile with only attn time reports zero fwd cpu-ms
        let only_attn = CompressProfile {
            threads: 1,
            wall_ms: 0.0,
            stages: vec![StageTiming { name: "attn", cpu_ms: 5.0, calls: 1 }],
        };
        assert_eq!(only_attn.fwd_ms(), 0.0);
        assert_eq!(only_attn.stage_ms("attn"), 5.0);
    }

    #[test]
    fn fwd_ms_sums_both_forward_stages_and_stage_calls_counts() {
        let _g = LOCK.lock().unwrap();
        let before = snapshot(0.0);
        let c0 = stage_calls(Stage::FwdLowrank);
        time(Stage::Fwd, || std::hint::black_box(1 + 1));
        time(Stage::FwdLowrank, || std::hint::black_box(2 + 2));
        let after = snapshot(0.0);
        assert!(after.fwd_ms() >= before.fwd_ms());
        assert!(stage_calls(Stage::FwdLowrank) >= c0 + 1);
        let calls = |p: &CompressProfile, name: &str| {
            p.stages.iter().find(|s| s.name == name).unwrap().calls
        };
        assert!(calls(&after, "fwd") >= calls(&before, "fwd") + 1);
        assert!(calls(&after, "fwd_lowrank") >= calls(&before, "fwd_lowrank") + 1);
    }

    #[test]
    fn reset_then_render_lists_every_stage() {
        let _g = LOCK.lock().unwrap();
        reset();
        let out = snapshot(0.0).render();
        for name in STAGE_NAMES {
            assert!(out.contains(name), "missing stage {name} in render");
        }
    }
}
