//! Byte-pair-encoding tokenizer (train / encode / decode / save / load).
//!
//! The corpus substrate emits text; the models consume token ids. Classic
//! word-bounded BPE: pre-tokenize on whitespace (a leading space is part of
//! the following word, GPT-2 style), then greedily merge the most frequent
//! adjacent pair until the target vocab size. Merges never cross word
//! boundaries, so encoding is word-local and cacheable.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Trained BPE vocabulary.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// token id -> surface string
    pub vocab: Vec<String>,
    /// (left id, right id) -> (merged id, rank); lower rank merges first
    merges: BTreeMap<(u32, u32), (u32, u32)>,
    /// byte -> base token id
    byte_ids: BTreeMap<u8, u32>,
}

impl Tokenizer {
    /// Train on `text` to a vocabulary of `vocab_size` tokens.
    pub fn train(text: &str, vocab_size: usize) -> Self {
        // base alphabet = bytes present in the corpus
        let mut byte_ids = BTreeMap::new();
        let mut vocab = Vec::new();
        for &b in text.as_bytes() {
            byte_ids.entry(b).or_insert_with(|| {
                vocab.push((b as char).to_string());
                (vocab.len() - 1) as u32
            });
        }
        assert!(
            vocab_size >= vocab.len(),
            "vocab_size {} below alphabet {}",
            vocab_size,
            vocab.len()
        );

        // unique words with counts (leading space kept with the word)
        let mut word_counts: BTreeMap<Vec<u32>, usize> = BTreeMap::new();
        for word in split_words(text) {
            let ids: Vec<u32> = word.bytes().map(|b| byte_ids[&b]).collect();
            *word_counts.entry(ids).or_insert(0) += 1;
        }
        let mut words: Vec<(Vec<u32>, usize)> = word_counts.into_iter().collect();

        let mut merges = BTreeMap::new();
        let mut rank = 0u32;
        while vocab.len() < vocab_size {
            // count adjacent pairs
            let mut pair_counts: BTreeMap<(u32, u32), usize> = BTreeMap::new();
            for (w, c) in &words {
                for p in w.windows(2) {
                    *pair_counts.entry((p[0], p[1])).or_insert(0) += c;
                }
            }
            // deterministic argmax: highest count, ties by smallest pair
            let Some((&pair, &count)) = pair_counts
                .iter()
                .max_by(|a, b| a.1.cmp(b.1).then_with(|| b.0.cmp(a.0)))
            else {
                break;
            };
            if count < 2 {
                break; // nothing worth merging
            }
            let new_id = vocab.len() as u32;
            let surface =
                format!("{}{}", vocab[pair.0 as usize], vocab[pair.1 as usize]);
            vocab.push(surface);
            merges.insert(pair, (new_id, rank));
            rank += 1;
            // apply merge to every word
            for (w, _) in &mut words {
                *w = apply_merge(w, pair, new_id);
            }
        }
        Self { vocab, merges, byte_ids }
    }

    /// Encode text into token ids.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::new();
        for word in split_words(text) {
            let mut ids: Vec<u32> = word
                .bytes()
                .filter_map(|b| self.byte_ids.get(&b).copied())
                .collect();
            // repeatedly apply the lowest-rank applicable merge
            loop {
                let mut best: Option<(usize, (u32, u32), u32)> = None; // (pos, rank+id)
                for (i, p) in ids.windows(2).enumerate() {
                    if let Some(&(id, r)) = self.merges.get(&(p[0], p[1])) {
                        if best.map(|(_, (_, br), _)| r < br).unwrap_or(true) {
                            best = Some((i, (id, r), id));
                        }
                    }
                }
                match best {
                    Some((i, _, id)) => {
                        ids[i] = id;
                        ids.remove(i + 1);
                    }
                    None => break,
                }
            }
            out.extend(ids);
        }
        out
    }

    /// Decode token ids back to text.
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.vocab.get(i as usize).map(|s| s.as_str()).unwrap_or(""))
            .collect()
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    // ---- serialization ----------------------------------------------------

    pub fn to_json(&self) -> Json {
        let merges: Vec<Json> = self
            .merges
            .iter()
            .map(|(&(a, b), &(id, r))| {
                Json::arr_num(&[a as f64, b as f64, id as f64, r as f64])
            })
            .collect();
        Json::obj(vec![
            (
                "vocab",
                Json::Arr(self.vocab.iter().map(|s| Json::str(s.clone())).collect()),
            ),
            ("merges", Json::Arr(merges)),
            (
                "bytes",
                Json::Arr(
                    self.byte_ids
                        .iter()
                        .map(|(&b, &id)| Json::arr_num(&[b as f64, id as f64]))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        let vocab = j
            .get("vocab")?
            .as_arr()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect::<Option<Vec<_>>>()?;
        let mut merges = BTreeMap::new();
        for m in j.get("merges")?.as_arr()? {
            let a = m.as_arr()?;
            merges.insert(
                (a[0].as_usize()? as u32, a[1].as_usize()? as u32),
                (a[2].as_usize()? as u32, a[3].as_usize()? as u32),
            );
        }
        let mut byte_ids = BTreeMap::new();
        for m in j.get("bytes")?.as_arr()? {
            let a = m.as_arr()?;
            byte_ids.insert(a[0].as_usize()? as u8, a[1].as_usize()? as u32);
        }
        Some(Self { vocab, merges, byte_ids })
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().emit())
    }

    pub fn load(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j).ok_or_else(|| anyhow::anyhow!("bad tokenizer json"))
    }
}

/// Split into words, each keeping its leading space: "a bc d" -> ["a", " bc", " d"].
fn split_words(text: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let bytes = text.as_bytes();
    let mut start = 0;
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b' ' && i > start {
            out.push(&text[start..i]);
            start = i;
        }
        i += 1;
    }
    if start < bytes.len() {
        out.push(&text[start..]);
    }
    out
}

fn apply_merge(w: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(w.len());
    let mut i = 0;
    while i < w.len() {
        if i + 1 < w.len() && w[i] == pair.0 && w[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(w[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const CORPUS: &str = "the cat sat on the mat the cat ran to the cat house \
                          a cat and a mat and the house on the mat";

    #[test]
    fn roundtrip_exact() {
        let tok = Tokenizer::train(CORPUS, 64);
        let ids = tok.encode(CORPUS);
        assert_eq!(tok.decode(&ids), CORPUS);
    }

    #[test]
    fn compresses_frequent_words() {
        let tok = Tokenizer::train(CORPUS, 64);
        let ids = tok.encode(" the cat");
        // " the" and " cat" are the most frequent words; both should be
        // single tokens (or near), so <= 4 tokens for 8 chars
        assert!(ids.len() <= 4, "{ids:?}");
    }

    #[test]
    fn unseen_text_still_roundtrips() {
        let tok = Tokenizer::train(CORPUS, 48);
        let text = " tame cats chant"; // unseen words, seen alphabet
        let ids = tok.encode(text);
        assert_eq!(tok.decode(&ids), text);
    }

    #[test]
    fn vocab_size_respected() {
        let tok = Tokenizer::train(CORPUS, 40);
        assert!(tok.vocab_size() <= 40);
    }

    #[test]
    fn serialization_roundtrip() {
        let tok = Tokenizer::train(CORPUS, 64);
        let j = tok.to_json();
        let tok2 = Tokenizer::from_json(&j).unwrap();
        let text = " the cat sat";
        assert_eq!(tok.encode(text), tok2.encode(text));
        assert_eq!(tok2.decode(&tok2.encode(text)), text);
    }

    #[test]
    fn deterministic_training() {
        let a = Tokenizer::train(CORPUS, 64);
        let b = Tokenizer::train(CORPUS, 64);
        assert_eq!(a.vocab, b.vocab);
    }

    #[test]
    fn encoding_never_crosses_words() {
        let tok = Tokenizer::train(CORPUS, 64);
        let a = tok.encode(" the");
        let b = tok.encode(" cat");
        let ab = tok.encode(" the cat");
        assert_eq!(ab, [a, b].concat());
    }
}
