//! D-Rank CLI: train / compress / eval / serve / generate / info.
//!
//! ```text
//! drank train    --model m --steps 400 [--lr 3e-3] [--scale 1.0]
//! drank compress --model m --method drank --ratio 0.2 [--group 2]
//!                [--beta 0.3] [--compensate] [--calib wiki2s] [--eval]
//!                [--threads N]
//! drank eval     --model m [--domains wiki2s,ptbs,c4s] [--tasks]
//! drank serve    --model m [--ratio 0.3] [--requests 200] [--clients 4]
//!                [--workers 1] [--backend xla|ref] [--queue 256]
//!                [--batch-window-ms 2] [--deadline-ms N]
//! drank generate --model m [--ratio 0.3] [--prompt-len 16] [--max-new 32]
//!                [--requests 8] [--temperature 0.0] [--seed 0]
//!                [--workers 1] [--threads N]
//! drank info
//! ```
//!
//! `serve --backend ref` runs the coordinator over the pure-Rust reference
//! forward — no `artifacts/` directory or PJRT needed (it even falls back
//! to random-init weights when no checkpoint exists, so a bare checkout
//! can exercise the full serving stack). With `--ratio > 0` the reference
//! backend serves the **factored** weights directly: every projection runs
//! as two skinny GEMMs (x·B)·C and the dense matrices are never
//! rematerialized (no `Reconstruct` stage calls — the `fwd_lowrank`
//! profile stage carries the work instead).
//!
//! `--threads N` sizes the one process-wide thread pool (any command;
//! defaults to the machine's available parallelism, or `DRANK_THREADS`).
//! Compression fan-out and the serving coordinator's scoring backends
//! share it (`ServerOpts::threads` carries the same value), and results
//! are bit-identical for any thread count.

use anyhow::{bail, Result};
use drank::calib::CalibOpts;
use drank::compress::{pipeline, CompressOpts, Method};
use drank::coordinator::{spawn_model_server, ScoreError, ServerOpts};
use drank::data::synlang::Domain;
use drank::data::DataBundle;
use drank::eval;
use drank::model::{ckpt_path, load_or_init, logical_model, Weights};
use drank::report::{fmt_acc, fmt_ppl, Table};
use drank::runtime::trainer::{self, TrainOpts};
use drank::runtime::Engine;
use drank::util::cli::Args;
use drank::util::json::Json;
use drank::util::Timer;

fn main() -> Result<()> {
    let args = Args::from_env();
    drank::util::parallel::set_threads(args.threads_or_default());
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "compress" => cmd_compress(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "info" => cmd_info(),
        _ => {
            println!("usage: drank <train|compress|eval|serve|generate|info> [--flags]");
            Ok(())
        }
    }
}

/// Load a trained checkpoint for a logical model (or fail with guidance).
fn load_ckpt(model: &str) -> Result<Weights> {
    load_or_init(model, false)
}

fn bundle_for(w: &Weights, scale: f64) -> DataBundle {
    DataBundle::build_cached(w.config.vocab, 1234, scale)
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.str_or("model", "m");
    let (cfg, seed) = logical_model(&model)?;
    let engine = Engine::open("artifacts")?;
    let data = DataBundle::build_cached(cfg.vocab, 1234, args.f64_or("scale", 1.0));
    let weights = Weights::init(cfg, seed);
    let opts = TrainOpts {
        steps: args.usize_or("steps", 400),
        base_lr: args.f64_or("lr", 3e-3),
        warmup: args.usize_or("warmup", 20),
        log_every: args.usize_or("log-every", 20),
        seed,
    };
    println!(
        "training {model} (config {}, {} params) for {} steps",
        cfg.name,
        weights.total_params(),
        opts.steps
    );
    let timer = Timer::start();
    let log = trainer::train(&engine, weights, &data, &opts)?;
    for (step, loss) in &log.losses {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    println!(
        "done in {:.1}s ({:.0} tokens/sec)",
        timer.secs(),
        log.tokens_per_sec
    );
    let path = ckpt_path(&model);
    log.final_weights.save(&path, opts.steps)?;
    // persist the loss curve for EXPERIMENTS.md §E2E
    let curve = Json::Arr(
        log.losses
            .iter()
            .map(|(s, l)| Json::arr_num(&[*s as f64, *l]))
            .collect(),
    );
    std::fs::create_dir_all(format!("runs/{model}"))?;
    std::fs::write(
        format!("runs/{model}/train_log.json"),
        Json::obj(vec![
            ("model", Json::str(model.clone())),
            ("steps", Json::num(opts.steps as f64)),
            ("tokens_per_sec", Json::num(log.tokens_per_sec)),
            ("curve", curve),
        ])
        .emit(),
    )?;
    println!("saved {path}");
    Ok(())
}

fn parse_compress_opts(args: &Args) -> Result<CompressOpts> {
    let opts = CompressOpts {
        method: Method::parse(&args.str_or("method", "drank"))?,
        ratio: args.f64_or("ratio", 0.2),
        group_layers: args.usize_or("group", 2),
        beta: args.f64_or("beta", 0.3),
        asvd_alpha: args.f64_or("alpha", 0.5),
        gqa_policy: !args.has("no-gqa-policy"),
        compensate: args.has("compensate"),
    };
    // reject out-of-range values (e.g. --beta 1.0) here with a typed error
    // instead of panicking deep inside the allocator
    opts.validate()?;
    Ok(opts)
}

fn cmd_compress(args: &Args) -> Result<()> {
    let model = args.str_or("model", "m");
    let weights = load_ckpt(&model)?;
    let engine = Engine::open("artifacts")?;
    let data = bundle_for(&weights, 1.0);
    let opts = parse_compress_opts(args)?;
    let copts = CalibOpts {
        domain: Domain::parse(&args.str_or("calib", "wiki2s"))
            .ok_or_else(|| anyhow::anyhow!("bad --calib"))?,
        batches: args.usize_or("calib-batches", 16),
        seed: args.u64_or("calib-seed", 13),
        fisher: opts.method == Method::Fwsvd,
    };
    println!(
        "compressing {model} with {} at ratio {:.0}% (n={}, beta={})",
        opts.method.name(),
        opts.ratio * 100.0,
        opts.group_layers,
        opts.beta
    );
    drank::util::profile::reset();
    let timer = Timer::start();
    let (compressed, plan) = pipeline::compress_model(&engine, &weights, &data, &copts, &opts)?;
    let prof = drank::util::profile::snapshot(timer.millis());
    println!(
        "achieved ratio {:.3} in {:.1}s",
        compressed.achieved_ratio(),
        timer.secs()
    );
    for (typ, ks) in &plan {
        println!("  {typ:<8} ranks {ks:?}");
    }
    print!("{}", prof.render());
    std::fs::create_dir_all("runs/reports")?;
    std::fs::write(
        format!("runs/reports/compress_profile_{model}.json"),
        Json::obj(vec![
            ("model", Json::str(model.clone())),
            ("method", Json::str(opts.method.name())),
            ("ratio", Json::num(opts.ratio)),
            ("profile", prof.to_json()),
        ])
        .emit(),
    )?;
    if args.has("eval") {
        let stream = &data.domain(Domain::Wiki2s).test;
        let ppl = eval::ppl_compressed(&engine, &compressed, stream, args.usize_or("eval-batches", 24))?;
        println!("wiki2s test PPL: {}", fmt_ppl(ppl));
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.str_or("model", "m");
    let weights = load_ckpt(&model)?;
    let engine = Engine::open("artifacts")?;
    let data = bundle_for(&weights, 1.0);
    let max_b = args.usize_or("eval-batches", 24);

    let mut table = Table::new(
        &format!("eval {model}"),
        &["Dataset", "PPL"],
    );
    for name in args.list_or("domains", "wiki2s,ptbs,c4s") {
        let d = Domain::parse(&name).ok_or_else(|| anyhow::anyhow!("bad domain {name}"))?;
        let ppl = eval::ppl_dense(&engine, &weights, &data.domain(d).test, max_b)?;
        table.row(vec![name, fmt_ppl(ppl)]);
    }
    print!("{}", table.markdown());

    if args.has("tasks") {
        let n = args.usize_or("task-items", 100);
        let (accs, avg) = eval::tasks::run_all_suites(
            &engine,
            &weights,
            &data.tokenizer,
            &data.lexicon,
            n,
            args.u64_or("task-seed", 17),
        )?;
        let mut t = Table::new("zero-shot", &["Suite", "Acc", "Chance"]);
        for (suite, acc) in accs {
            t.row(vec![
                suite.name().to_string(),
                fmt_acc(acc),
                fmt_acc(eval::tasks::chance(suite)),
            ]);
        }
        t.row(vec!["Average*".into(), fmt_acc(avg), "-".into()]);
        print!("{}", t.markdown());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.str_or("model", "m");
    let backend = args.str_or("backend", "xla");
    anyhow::ensure!(
        backend == "xla" || backend == "ref",
        "bad --backend {backend} (expected xla or ref)"
    );
    // the reference backend can serve a bare checkout: fall back to
    // random-init weights when no checkpoint file exists (a corrupt
    // checkpoint is still a hard error)
    let weights = load_or_init(&model, backend == "ref")?;
    let cfg = weights.config;
    let data = bundle_for(&weights, 1.0);
    let ratio = args.f64_or("ratio", 0.0);
    let n_requests = args.usize_or("requests", 200);
    let n_clients = args.usize_or("clients", 4);

    // optionally compress before serving (reference calibration when the
    // reference backend was chosen, so no artifacts are needed)
    let served = if ratio > 0.0 {
        let opts = parse_compress_opts(args)?;
        let copts = CalibOpts::default();
        let m = if backend == "ref" {
            let (m, _) = pipeline::compress_model_reference(
                &weights, &data, &copts, &CompressOpts { ratio, ..opts },
            )?;
            m
        } else {
            let engine = Engine::open("artifacts")?;
            let (m, _) = pipeline::compress_model(
                &engine, &weights, &data, &copts, &CompressOpts { ratio, ..opts },
            )?;
            m
        };
        if backend == "ref" {
            println!(
                "serving compressed model (ratio {:.2}) on its factors — dense weights \
                 are never rematerialized",
                m.achieved_ratio()
            );
        } else {
            println!("serving compressed model (ratio {:.2})", m.achieved_ratio());
        }
        m
    } else {
        drank::model::lowrank::CompressedModel::dense_passthrough(weights)
    };

    let sopts = ServerOpts {
        workers: args.usize_or("workers", 1),
        queue: args.usize_or("queue", 256),
        batch_window: args.duration_ms_or("batch-window-ms", 2),
        deadline: args
            .opt_usize("deadline-ms")
            .map(|ms| std::time::Duration::from_millis(ms as u64)),
        // main() already sized the pool from --threads; pass an explicit
        // value through so ServerOpts-driven embedders get the same knob
        threads: args.opt_usize("threads").unwrap_or(0),
        ..Default::default()
    };
    println!("spawning {} worker(s) on the {backend} backend", sopts.workers);
    let server = spawn_model_server(served, cfg.batch, cfg.seq, &backend, sopts)?;
    // drive load from client threads
    let stream = data.domain(Domain::Wiki2s).test.clone();
    let per_client = n_requests / n_clients;
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = server.client();
        let stream = stream.clone();
        let seq = cfg.seq;
        handles.push(std::thread::spawn(move || {
            let mut rng = drank::util::rng::Rng::new(c as u64);
            for _ in 0..per_client {
                let start = rng.below(stream.len() - seq);
                let toks = stream[start..start + seq].to_vec();
                match client.score(toks) {
                    Ok(_) => {}
                    // load-shedding rejections are expected under
                    // --deadline-ms; the server counts them
                    Err(ScoreError::Timeout) | Err(ScoreError::QueueFull) => {}
                    Err(e) => {
                        eprintln!("client {c}: {e}");
                        return;
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.shutdown()?;
    println!(
        "served {} requests ({} rejected), {:.0} tokens/s, p50 {:.1} ms, p99 {:.1} ms, \
         occupancy {:.2}, padding eff {:.2}, mean queue depth {:.1}, utilization {:.2}",
        m.requests,
        m.rejected(),
        m.throughput_tps(),
        m.p50_ms(),
        m.p99_ms(),
        m.mean_batch_occupancy(),
        m.padding_efficiency(),
        m.mean_queue_depth(),
        m.utilization()
    );
    for (i, wm) in m.per_worker.iter().enumerate() {
        println!(
            "  worker {i}: {} batches, {} requests, {} tokens, busy {:.2}s",
            wm.batches, wm.requests, wm.tokens, wm.busy_secs
        );
    }
    Ok(())
}

/// `drank generate`: KV-cached autoregressive decoding through the serving
/// coordinator on the reference backend (the compiled XLA graph has no
/// decode path and would answer with the typed `NotGenerative` rejection).
/// Prompts are drawn from the wiki2s test stream; `--ratio > 0` first
/// compresses the model and decodes on the factors directly — every
/// single-token projection runs as two skinny vec×mat products and the
/// dense weights are never rematerialized.
fn cmd_generate(args: &Args) -> Result<()> {
    let model = args.str_or("model", "m");
    let weights = load_or_init(&model, true)?;
    let cfg = weights.config;
    let data = bundle_for(&weights, 1.0);
    let ratio = args.f64_or("ratio", 0.0);
    let prompt_len = args.usize_or("prompt-len", 16);
    let max_new = args.usize_or("max-new", 32);
    let n_requests = args.usize_or("requests", 8);
    let temperature = args.f64_or("temperature", 0.0);
    let seed = args.u64_or("seed", 0);
    anyhow::ensure!(prompt_len >= 1, "--prompt-len must be at least 1");
    anyhow::ensure!(
        prompt_len + max_new <= cfg.seq,
        "--prompt-len {prompt_len} + --max-new {max_new} exceeds seq {}",
        cfg.seq
    );

    let served = if ratio > 0.0 {
        let opts = parse_compress_opts(args)?;
        let copts = CalibOpts::default();
        let (m, _) = pipeline::compress_model_reference(
            &weights, &data, &copts, &CompressOpts { ratio, ..opts },
        )?;
        println!(
            "generating on the factors of a compressed model (ratio {:.2})",
            m.achieved_ratio()
        );
        m
    } else {
        drank::model::lowrank::CompressedModel::dense_passthrough(weights)
    };

    let sopts = ServerOpts {
        workers: args.usize_or("workers", 1),
        queue: args.usize_or("queue", 256),
        batch_window: args.duration_ms_or("batch-window-ms", 2),
        threads: args.opt_usize("threads").unwrap_or(0),
        ..Default::default()
    };
    let server = spawn_model_server(served, cfg.batch, cfg.seq, "ref", sopts)?;
    let client = server.client();
    let stream = data.domain(Domain::Wiki2s).test.clone();
    let mut rng = drank::util::rng::Rng::new(seed);
    for r in 0..n_requests {
        let start = rng.below(stream.len() - prompt_len);
        let prompt = stream[start..start + prompt_len].to_vec();
        let resp = client
            .generate_sampled(prompt, max_new, temperature, seed.wrapping_add(r as u64))
            .map_err(|e| anyhow::anyhow!("generate request failed: {e}"))?;
        println!(
            "  request {r}: {} new tokens in {:.1} ms  {:?}",
            resp.tokens.len(),
            resp.latency_ms,
            &resp.tokens[..resp.tokens.len().min(12)]
        );
    }
    let m = server.shutdown()?;
    println!(
        "generated {} tokens over {} requests: {:.0} tokens/s decode, p50 {:.1} ms, \
         p99 {:.1} ms",
        m.generated_tokens,
        m.requests,
        m.decode_tps(),
        m.p50_ms(),
        m.p99_ms()
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let engine = Engine::open("artifacts")?;
    println!("pjrt platform: {}", engine.rt.platform());
    for cfg in drank::model::CONFIGS {
        let w = Weights::init(cfg, 0);
        println!(
            "config {:<5} d={} L={} H={}/{} dff={} vocab={} params={}",
            cfg.name, cfg.d, cfg.layers, cfg.heads, cfg.kv_heads, cfg.dff, cfg.vocab,
            w.total_params()
        );
    }
    for m in ["tiny", "s", "m", "m2", "l", "gqa", "mist"] {
        let have = std::path::Path::new(&ckpt_path(m)).exists();
        println!("model {m:<5} checkpoint: {}", if have { "yes" } else { "no" });
    }
    Ok(())
}

#[allow(dead_code)]
fn unused(_: &str) -> Result<()> {
    bail!("unreachable")
}
