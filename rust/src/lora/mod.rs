//! LoRA recovery fine-tuning of compressed models (paper Figure 3).
//!
//! The compressed factors are frozen; rank-8 adapters (P: d1×8, Q: 8×d2,
//! scaled by α/r = 4) train on the calibration-domain stream through the
//! AOT `lora_step` artifact. Factors enter the artifact zero-padded to
//! kpad = min(d1, d2) (exact). After fine-tuning, ΔW = (α/r)·P·Q merges
//! into a dense reconstruction for evaluation.

use anyhow::{bail, Result};

use crate::data::synlang::Domain;
use crate::data::{Batcher, DataBundle};
use crate::model::lowrank::CompressedModel;
use crate::model::{ModelConfig, Tensor, Weights, COMPRESSIBLE};
use crate::runtime::engine::tensor_of;
use crate::runtime::{lit_f32, lit_i32, lit_scalar, Engine};

pub const LORA_RANK: usize = 8;
pub const LORA_SCALE: f32 = 32.0 / LORA_RANK as f32; // alpha / r

pub struct LoraOpts {
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
    pub domain: Domain,
}

impl Default for LoraOpts {
    fn default() -> Self {
        Self { steps: 30, lr: 1e-3, seed: 0, domain: Domain::Wiki2s }
    }
}

/// Zero-padded factored parameter tensors in lora_step wire order
/// (19 tensors; see python lowrank_param_shapes).
fn padded_lr_params(model: &CompressedModel) -> Result<Vec<Tensor>> {
    let cfg = model.config();
    let w = &model.base;
    let mut out: Vec<Tensor> = Vec::with_capacity(19);
    out.push(w.by_name("embed").clone());
    out.push(w.by_name("attn_norm").clone());
    fn push_type(
        out: &mut Vec<Tensor>,
        model: &CompressedModel,
        cfg: &ModelConfig,
        w: &Weights,
        typ: &str,
    ) -> Result<()> {
        let (d1, d2) = cfg.matrix_dims(typ);
        let kpad = d1.min(d2);
        let mut b = Tensor::zeros(vec![cfg.layers, d1, kpad]);
        let mut c = Tensor::zeros(vec![cfg.layers, kpad, d2]);
        for l in 0..cfg.layers {
            match model.layer_factors(typ, l) {
                Some((bm, cm)) => {
                    let k = bm.cols;
                    if k > kpad {
                        bail!("{typ} layer {l}: rank {k} exceeds pad {kpad}");
                    }
                    for r in 0..d1 {
                        for j in 0..k {
                            b.data[(l * d1 + r) * kpad + j] = bm.at(r, j);
                        }
                    }
                    for r in 0..k {
                        for j in 0..d2 {
                            c.data[(l * kpad + r) * d2 + j] = cm.at(r, j);
                        }
                    }
                }
                None => {
                    // dense type: exact full factorization W = W · I
                    let pidx = ModelConfig::param_index(typ);
                    let wm = w.tensors[pidx].layer_mat(l);
                    if d1 <= d2 {
                        // B = I (d1 x d1 = kpad), C = W
                        for r in 0..d1 {
                            b.data[(l * d1 + r) * kpad + r] = 1.0;
                        }
                        for r in 0..d1 {
                            for j in 0..d2 {
                                c.data[(l * kpad + r) * d2 + j] = wm.at(r, j);
                            }
                        }
                    } else {
                        // B = W, C = I (d2 x d2 = kpad)
                        for r in 0..d1 {
                            for j in 0..d2 {
                                b.data[(l * d1 + r) * kpad + j] = wm.at(r, j);
                            }
                        }
                        for r in 0..d2 {
                            c.data[(l * kpad + r) * d2 + r] = 1.0;
                        }
                    }
                }
            }
        }
        out.push(b);
        out.push(c);
        Ok(())
    }
    for typ in ["wq", "wk", "wv", "wo"] {
        push_type(&mut out, model, &cfg, w, typ)?;
        if typ == "wo" {
            out.push(w.by_name("mlp_norm").clone());
        }
    }
    for typ in ["w_gate", "w_up", "w_down"] {
        push_type(&mut out, model, &cfg, w, typ)?;
    }
    out.push(w.by_name("final_norm").clone());
    out.push(w.by_name("lm_head").clone());
    Ok(out)
}

/// Test-only re-export of the padded factor construction (the integration
/// suite cross-checks the Pallas lowrank artifact against dense execution).
pub fn padded_params_for_tests(model: &CompressedModel) -> Result<Vec<Tensor>> {
    padded_lr_params(model)
}

/// Adapter tensors (p, q per compressible type), canonical order.
fn init_adapters(cfg: &ModelConfig, seed: u64) -> Vec<Tensor> {
    let mut rng = crate::util::rng::Rng::new(seed ^ 0x10_8A);
    let mut out = Vec::with_capacity(14);
    for typ in COMPRESSIBLE {
        let (d1, d2) = cfg.matrix_dims(typ);
        let mut p = Tensor::zeros(vec![cfg.layers, d1, LORA_RANK]);
        for v in &mut p.data {
            *v = 0.02 * rng.normal() as f32;
        }
        let q = Tensor::zeros(vec![cfg.layers, LORA_RANK, d2]); // zeros: identity start
        out.push(p);
        out.push(q);
    }
    out
}

/// Result of a LoRA run.
pub struct LoraLog {
    pub losses: Vec<(usize, f64)>,
    /// dense weights with ΔW merged (for evaluation)
    pub merged: Weights,
}

/// Fine-tune adapters on a frozen compressed model.
pub fn finetune(
    engine: &Engine,
    model: &CompressedModel,
    data: &DataBundle,
    opts: &LoraOpts,
) -> Result<LoraLog> {
    let cfg = model.config();
    if !engine.has(cfg.name, "lora_step") {
        bail!("no lora_step artifact for config {}", cfg.name);
    }
    let lr_params = padded_lr_params(model)?;
    let mut adapters = init_adapters(&cfg, opts.seed);
    let mut m: Vec<Tensor> = adapters.iter().map(|t| Tensor::zeros(t.shape.clone())).collect();
    let mut v: Vec<Tensor> = adapters.iter().map(|t| Tensor::zeros(t.shape.clone())).collect();
    let lr_lits: Vec<xla::Literal> = lr_params
        .iter()
        .map(|t| lit_f32(&t.data, &t.shape))
        .collect::<Result<_>>()?;

    let stream = &data.domain(opts.domain).train;
    let mut batcher = Batcher::new(stream, cfg.batch, cfg.seq, opts.seed ^ 0x70_AD);
    let mut losses = Vec::new();
    for step in 0..opts.steps {
        let batch = batcher.next_batch();
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(64);
        // (engine.exec is generic over Borrow; build owned tail, chain refs)
        let tail: Vec<xla::Literal> = adapters
            .iter()
            .chain(&m)
            .chain(&v)
            .map(|t| lit_f32(&t.data, &t.shape))
            .collect::<Result<_>>()?;
        inputs.extend(tail);
        inputs.push(lit_scalar((step + 1) as f32));
        inputs.push(lit_scalar(opts.lr as f32));
        inputs.push(lit_i32(&batch, &[cfg.batch, cfg.seq])?);
        let all: Vec<&xla::Literal> = lr_lits.iter().chain(inputs.iter()).collect();
        let outs = engine.exec(cfg.name, "lora_step", &all)?;
        let loss = outs[0].to_vec::<f32>()?[0] as f64;
        let na = adapters.len();
        for i in 0..na {
            adapters[i].data = tensor_of(&outs[1 + i])?.0;
            m[i].data = tensor_of(&outs[1 + na + i])?.0;
            v[i].data = tensor_of(&outs[1 + 2 * na + i])?.0;
        }
        losses.push((step, loss));
        if !loss.is_finite() {
            bail!("lora loss diverged at step {step}");
        }
    }

    // merge ΔW = scale * P·Q into the dense reconstruction
    let mut merged = model.to_dense();
    for (ti, typ) in COMPRESSIBLE.iter().enumerate() {
        let (d1, d2) = cfg.matrix_dims(typ);
        let pidx = ModelConfig::param_index(typ);
        let p = &adapters[2 * ti];
        let q = &adapters[2 * ti + 1];
        for l in 0..cfg.layers {
            let wt = &mut merged.tensors[pidx];
            for r in 0..d1 {
                for j in 0..d2 {
                    let mut acc = 0.0f32;
                    for t in 0..LORA_RANK {
                        acc += p.data[(l * d1 + r) * LORA_RANK + t]
                            * q.data[(l * LORA_RANK + t) * d2 + j];
                    }
                    wt.data[(l * d1 + r) * d2 + j] += LORA_SCALE * acc;
                }
            }
        }
    }
    // merged came from to_dense() (a fresh clone, empty pack cache), but be
    // explicit: the in-place delta invalidates any packed panels
    merged.reset_packs();
    Ok(LoraLog { losses, merged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::CalibStats;
    use crate::compress::{methods, CompressOpts, Method};

    #[test]
    fn padded_params_shapes_and_exactness() {
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 3);
        let stats = CalibStats::synthetic(&cfg, 4);
        let opts = CompressOpts { method: Method::DRank, ratio: 0.3, group_layers: 2, ..Default::default() };
        let (model, _) = methods::compress(&w, &stats, &opts).unwrap();
        let lp = padded_lr_params(&model).unwrap();
        assert_eq!(lp.len(), 19);
        // padded factors must reconstruct the same dense model
        let dense = model.to_dense();
        // check wq layer 0: B_pad @ C_pad == dense wq[0]
        let (d1, d2) = cfg.matrix_dims("wq");
        let kpad = d1.min(d2);
        let b = &lp[2];
        let c = &lp[3];
        let want = dense.by_name("wq").layer_mat(0);
        for r in 0..d1 {
            for j in 0..d2 {
                let mut acc = 0.0f32;
                for t in 0..kpad {
                    acc += b.data[r * kpad + t] * c.data[t * d2 + j];
                }
                assert!((acc - want.at(r, j)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn dense_passthrough_pads_exactly() {
        // a dense (unfactored) type goes through the identity-factor path;
        // B_pad @ C_pad must equal the original weight bit-for-bit-ish
        let cfg = ModelConfig::by_name("tiny").unwrap();
        let w = Weights::init(cfg, 5);
        let model = CompressedModel::dense_passthrough(w.clone());
        let lp = padded_lr_params(&model).unwrap();
        // wire order: embed, attn_norm, wq(b,c), wk(b,c), wv(b,c), wo(b,c),
        //             mlp_norm, w_gate(b,c), w_up(b,c), w_down(b,c), ...
        // w_down is dff x d (d1 > d2): B = W, C = I path
        let (d1, d2) = cfg.matrix_dims("w_down");
        let kpad = d1.min(d2);
        let b = &lp[15];
        let c = &lp[16];
        assert_eq!(b.shape, vec![cfg.layers, d1, kpad]);
        assert_eq!(c.shape, vec![cfg.layers, kpad, d2]);
        let want = w.by_name("w_down").layer_mat(1);
        for r in 0..d1 {
            for j in 0..d2 {
                let mut acc = 0.0f32;
                for t in 0..kpad {
                    acc += b.data[(d1 * kpad) + r * kpad + t] * c.data[(kpad * d2) + t * d2 + j];
                }
                assert!((acc - want.at(r, j)).abs() < 1e-5, "({r},{j})");
            }
        }
    }
}
