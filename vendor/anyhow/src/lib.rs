//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! implements exactly the subset drank uses: `Error`, `Result`, the
//! `anyhow!` / `bail!` / `ensure!` macros, and the `Context` extension
//! trait. Error values carry a human-readable context chain (outermost
//! message first, like real anyhow's Display/Debug split).

use std::fmt;

/// A string-chained error value. `Display` shows the outermost context;
/// `Debug` shows the whole chain (what `.unwrap()` prints).
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Self { msg: c.to_string(), cause: Some(Box::new(self)) }
    }

    /// The full chain, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // keep one level of the std source chain for diagnostics
        match e.source() {
            Some(s) => Error { msg: e.to_string(), cause: Some(Box::new(Error::msg(s))) },
            None => Error::msg(e.to_string()),
        }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => { $crate::Error::msg(format!($($t)*)) };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root cause {}", 42)
    }

    #[test]
    fn macros_and_context() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(e.chain(), vec!["outer", "root cause 42"]);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("root cause 42"));
    }

    #[test]
    fn ensure_and_from_std() {
        fn check(x: usize) -> Result<usize> {
            ensure!(x > 2, "x too small: {x}");
            Ok(x)
        }
        assert!(check(1).is_err());
        assert_eq!(check(3).unwrap(), 3);
        let io = std::fs::read("/definitely/not/a/file");
        let e: Error = io.unwrap_err().into();
        assert!(!format!("{e}").is_empty());
    }
}
