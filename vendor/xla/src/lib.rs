//! Offline stub of the `xla` (xla-rs / xla_extension) API surface drank uses.
//!
//! The build container has no network and no PJRT shared library, so this
//! vendored crate keeps the whole workspace compiling and testable:
//!
//! - [`Literal`] is a *real* host-side implementation (flat f32/i32 buffers
//!   with shapes) — literal construction, reshape, and readback all work.
//! - Every PJRT / graph-building entry point (`PjRtClient::cpu`,
//!   `HloModuleProto::from_text_file`, `XlaBuilder::parameter`, ...) returns
//!   an [`XlaError`] explaining that the real bindings are absent. Handle
//!   types behind those entry points are uninhabitable, so downstream code
//!   type-checks but can never reach an execute path.
//!
//! To run with real PJRT, point the `xla` path dependency in the root
//! `Cargo.toml` at the actual xla-rs bindings; drank's runtime code gates
//! every artifact/JIT path on these constructors, so no other change is
//! needed (tests skip themselves when PJRT is unavailable).

use std::fmt;

#[derive(Debug, Clone)]
pub struct XlaError {
    msg: String,
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError {
        msg: format!(
            "{what} requires the real xla/PJRT bindings; this build uses the \
             offline stub (vendor/xla) — see Cargo.toml to swap them in"
        ),
    }
}

fn shape_error(msg: String) -> XlaError {
    XlaError { msg }
}

/// Uninhabitable marker: handle types holding it can never be constructed.
#[derive(Debug, Clone)]
enum Void {}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

// ---------------------------------------------------------------- literals

#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn into_payload(v: Vec<Self>) -> Payload;
    #[doc(hidden)]
    fn from_payload(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn into_payload(v: Vec<Self>) -> Payload {
        Payload::F32(v)
    }
    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            Payload::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn into_payload(v: Vec<Self>) -> Payload {
        Payload::I32(v)
    }
    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            Payload::F32(_) => None,
        }
    }
}

/// Host-side tensor value (fully functional in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a flat slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let n = data.len() as i64;
        Literal { payload: T::into_payload(data.to_vec()), dims: vec![n] }
    }

    /// 0-D scalar literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { payload: T::into_payload(vec![x]), dims: Vec::new() }
    }

    fn len(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }

    /// Same data, new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.len() {
            return Err(shape_error(format!(
                "reshape: {} elements into shape {:?}",
                self.len(),
                dims
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Flat readback with an element-type check.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_payload(&self.payload)
            .ok_or_else(|| shape_error("to_vec: element type mismatch".into()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| shape_error("get_first_element: empty literal".into()))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Decompose a tuple literal (only produced by execution, which the
    /// stub cannot reach).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple literals (execution output)"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("tuple literals (execution output)"))
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ------------------------------------------------------------ PJRT handles

pub struct PjRtClient {
    _void: Void,
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu (the PJRT runtime)"))
    }

    pub fn platform_name(&self) -> String {
        unreachable!("stub PjRtClient cannot be constructed")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unreachable!("stub PjRtClient cannot be constructed")
    }
}

pub struct PjRtLoadedExecutable {
    _void: Void,
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unreachable!("stub PjRtLoadedExecutable cannot be constructed")
    }
}

pub struct PjRtBuffer {
    _void: Void,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unreachable!("stub PjRtBuffer cannot be constructed")
    }
}

pub struct HloModuleProto {
    _void: Void,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HLO text parsing"))
    }
}

pub struct XlaComputation {
    _void: Void,
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        unreachable!("stub HloModuleProto cannot be constructed")
    }
}

// ------------------------------------------------------------ graph builder

/// Graph builder handle. Constructible, but every op constructor fails
/// with a clear error (graph building needs the real bindings).
#[derive(Clone)]
pub struct XlaBuilder {
    _name: String,
}

impl XlaBuilder {
    pub fn new(name: &str) -> Self {
        Self { _name: name.to_string() }
    }

    pub fn parameter(
        &self,
        _id: i64,
        _ty: ElementType,
        _dims: &[i64],
        _name: &str,
    ) -> Result<XlaOp> {
        Err(unavailable("XlaBuilder graph construction"))
    }

    pub fn iota(&self, _ty: ElementType, _dims: &[i64], _dim: i64) -> Result<XlaOp> {
        Err(unavailable("XlaBuilder graph construction"))
    }

    pub fn c0(&self, _v: f32) -> Result<XlaOp> {
        Err(unavailable("XlaBuilder graph construction"))
    }

    pub fn constant_literal(&self, _l: &Literal) -> Result<XlaOp> {
        Err(unavailable("XlaBuilder graph construction"))
    }

    pub fn tuple(&self, _ops: &[XlaOp]) -> Result<XlaOp> {
        Err(unavailable("XlaBuilder graph construction"))
    }

    pub fn build(&self, _root: &XlaOp) -> Result<XlaComputation> {
        Err(unavailable("XlaBuilder graph construction"))
    }
}

/// Graph op handle: uninhabitable in the stub (no builder method can
/// produce one), so these methods are statically unreachable.
pub struct XlaOp {
    _void: Void,
}

#[allow(unused_variables)]
impl XlaOp {
    fn gone<T>(&self) -> T {
        unreachable!("stub XlaOp cannot be constructed")
    }

    pub fn builder(&self) -> XlaBuilder {
        self.gone()
    }

    pub fn dims(&self) -> Result<Vec<usize>> {
        self.gone()
    }

    pub fn slice_in_dim1(&self, start: i64, stop: i64, dim: i64) -> Result<XlaOp> {
        self.gone()
    }

    pub fn take(&self, indices: &XlaOp, dim: i64) -> Result<XlaOp> {
        self.gone()
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<XlaOp> {
        self.gone()
    }

    pub fn broadcast_in_dim(&self, out_dims: &[i64], broadcast_dims: &[i64]) -> Result<XlaOp> {
        self.gone()
    }

    pub fn transpose(&self, perm: &[i64]) -> Result<XlaOp> {
        self.gone()
    }

    pub fn concat_in_dim(&self, others: &[&XlaOp], dim: i64) -> Result<XlaOp> {
        self.gone()
    }

    pub fn dot_general(
        &self,
        rhs: &XlaOp,
        lhs_contracting: &[i64],
        rhs_contracting: &[i64],
        lhs_batch: &[i64],
        rhs_batch: &[i64],
    ) -> Result<XlaOp> {
        self.gone()
    }

    pub fn add_(&self, rhs: &XlaOp) -> Result<XlaOp> {
        self.gone()
    }

    pub fn sub_(&self, rhs: &XlaOp) -> Result<XlaOp> {
        self.gone()
    }

    pub fn mul_(&self, rhs: &XlaOp) -> Result<XlaOp> {
        self.gone()
    }

    pub fn eq(&self, rhs: &XlaOp) -> Result<XlaOp> {
        self.gone()
    }

    pub fn le(&self, rhs: &XlaOp) -> Result<XlaOp> {
        self.gone()
    }

    pub fn select(&self, on_true: &XlaOp, on_false: &XlaOp) -> Result<XlaOp> {
        self.gone()
    }

    pub fn exp(&self) -> Result<XlaOp> {
        self.gone()
    }

    pub fn log(&self) -> Result<XlaOp> {
        self.gone()
    }

    pub fn rsqrt(&self) -> Result<XlaOp> {
        self.gone()
    }

    pub fn silu(&self) -> Result<XlaOp> {
        self.gone()
    }

    pub fn softmax(&self, dim: i64) -> Result<XlaOp> {
        self.gone()
    }

    pub fn reduce_max(&self, dims: &[i64], keep: bool) -> Result<XlaOp> {
        self.gone()
    }

    pub fn reduce_sum(&self, dims: &[i64], keep: bool) -> Result<XlaOp> {
        self.gone()
    }

    pub fn reduce_mean(&self, dims: &[i64], keep: bool) -> Result<XlaOp> {
        self.gone()
    }

    pub fn convert(&self, ty: PrimitiveType) -> Result<XlaOp> {
        self.gone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[7]).is_err());
        let s = Literal::scalar(4.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 4.5);
        let i = Literal::vec1(&[7i32, 8]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn pjrt_paths_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let b = XlaBuilder::new("g");
        assert!(b.parameter(0, ElementType::F32, &[2, 2], "p").is_err());
        assert!(b.c0(1.0).is_err());
    }
}
